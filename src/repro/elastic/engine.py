"""An elastic serving engine: K nested-width subnets resident behind
one ``submit()``/``step()`` front end, switchable at batch boundaries.

:class:`ElasticEngine` extends :class:`~repro.serving.ServingEngine`
with a *level* axis orthogonal to the existing configuration hot swap:
each level is a (model, packed, configuration) triple from an
:class:`~repro.elastic.planner.ElasticPlan`, compiled pipelines are
cached per level, and :meth:`set_level` republishes
``model``/``packed_params``/``config``/``pipeline`` together — with
the same batch-boundary atomicity as ``swap_configuration`` (a switch
requested mid-step is deferred to the end of the in-flight
wave-train; the incoming level's pipeline is built *before* the
outgoing one is released).  Because narrower packed params are prefix
views of the base tensors, K resident levels cost one model's weights
plus K compiled pipelines.

``quality_floor`` is the deepest level index the engine may ever
serve (0 pins full width).  It is enforced *here*, at the actuator —
the :class:`~repro.fleet.router.QualityController` respects it when
choosing transitions, but a bug above this line still cannot push a
tenant below its floor.

``swap_configuration`` stays fully functional and is *routed by model
name*: the cluster's joint remap hands a level-0 configuration, the
adaptive controller may hand one for whatever level telemetry was
watching — each lands on its level's slot (invalidating that level's
cached pipeline) and only touches the live pipeline when that level
is the one currently serving.
"""

from __future__ import annotations

from repro.elastic.planner import ElasticPlan
from repro.serving.engine import ServingEngine


class ElasticEngine(ServingEngine):
    def __init__(
        self,
        plan: ElasticPlan,
        *,
        config=None,
        quality_floor: int | None = None,
        **kwargs,
    ):
        """`plan` carries the per-level models/params/configurations.
        `config` overrides level 0's configuration (the cluster tier
        passes the joint contention-priced mapping here; solo serving
        leaves it as planned).  `quality_floor` is the deepest
        permitted level (default: the narrowest level in the plan).
        Remaining kwargs are the :class:`ServingEngine` knobs."""
        if len(plan) < 2:
            raise ValueError(
                "an elastic engine needs at least two subnet levels; "
                "use ServingEngine for a fixed model"
            )
        self.plan = plan
        self._level_configs = list(plan.configs)
        if config is not None:
            self._level_configs[0] = config
        batches = {c.proper_batch_size for c in self._level_configs}
        if len(batches) != 1:
            raise ValueError(
                f"level configurations disagree on proper batch size "
                f"{sorted(batches)}; level switches swap at batch "
                "boundaries and cannot re-batch"
            )
        floor = len(plan) - 1 if quality_floor is None else int(quality_floor)
        if not 0 <= floor < len(plan):
            raise ValueError(
                f"quality_floor {floor} outside levels "
                f"[0, {len(plan) - 1}]"
            )
        self.quality_floor = floor
        self.level = 0
        self.level_switches = 0
        self.degraded_steps = 0      # non-empty steps served below full width
        self._pending_level: int | None = None
        self._pipelines: dict = {}   # level -> compiled SegmentPipeline
        base = plan.levels[0]
        # ServingEngine.__init__ compiles level 0's pipeline through
        # _build_pipeline — the subclass seam taxed/instrumented
        # engines override — so every attribute it could touch is set
        # above, before this call
        super().__init__(
            base.model, base.packed, self._level_configs[0], **kwargs
        )
        self._pipelines[0] = self.pipeline

    # -- level plumbing ---------------------------------------------
    @property
    def n_levels(self) -> int:
        return len(self.plan)

    @property
    def degraded_share(self) -> float:
        """Fraction of non-empty steps served below full width."""
        return self.degraded_steps / self.steps if self.steps else 0.0

    def can_degrade(self) -> bool:
        return self.level < self.quality_floor

    def can_restore(self) -> bool:
        return self.level > 0

    def level_config(self, k: int):
        """Level `k`'s current configuration (the planned one, or the
        latest ``swap_configuration`` routed to it)."""
        return self._level_configs[k]

    def _pipeline_for(self, k: int):
        """Level `k`'s compiled pipeline, building (and caching) it on
        first use.  The build goes through ``_build_pipeline`` with
        the level's model/params temporarily published so subclass
        wrappers (contention-taxed engines) apply to every level."""
        pipe = self._pipelines.get(k)
        if pipe is None:
            tp = self.plan.levels[k]
            saved = (self.model, self.packed_params)
            self.model, self.packed_params = tp.model, tp.packed
            try:
                pipe = self._build_pipeline(self._level_configs[k])
            finally:
                self.model, self.packed_params = saved
            self._pipelines[k] = pipe
        return pipe

    def warm(self) -> None:
        """Pre-compile every level's pipeline so the first degrade
        under overload doesn't stall on a build."""
        for k in range(len(self.plan)):
            self._pipeline_for(k)

    def set_level(self, k: int) -> bool:
        """Serve subnet level `k` from the next batch boundary on.

        Returns True when applied immediately, False when deferred to
        the end of the executing step (mirroring
        :meth:`swap_configuration`).  Raises when `k` violates the
        engine's ``quality_floor`` — the floor binds at the actuator.
        """
        k = int(k)
        if not 0 <= k < len(self.plan):
            raise ValueError(
                f"level {k} outside [0, {len(self.plan) - 1}]"
            )
        if k > self.quality_floor:
            raise ValueError(
                f"level {k} violates quality_floor {self.quality_floor}"
            )
        if k == self.level and self._pending_level is None:
            return True
        if self._in_step:
            self._pending_level = k
            return False
        self._apply_level(k)
        return True

    def _apply_level(self, k: int) -> None:
        if k == self.level:
            return
        pipe = self._pipeline_for(k)   # build first: a failed compile
        #                                leaves the current level serving
        self._pipelines[self.level] = self.pipeline
        tp = self.plan.levels[k]
        self.model = tp.model
        self.packed_params = tp.packed
        self.config = self._level_configs[k]
        self.pipeline = pipe
        self.level = k
        self.level_switches += 1
        if self.telemetry is not None:
            # segment shapes changed: stale windows would register as
            # drift against the new level's predictions
            self.telemetry.reset()

    # -- ServingEngine overrides -------------------------------------
    def swap_configuration(self, config) -> bool:
        """Route `config` to the level whose model it was mapped for.

        A swap for the *serving* level behaves exactly like the parent
        (applied now or at the batch boundary); a swap for a dormant
        level just replaces that level's slot and drops its cached
        pipeline, taking effect whenever the level is next served."""
        target = None
        for k, c in enumerate(self._level_configs):
            if c.model_name == config.model_name:
                target = k
                break
        if target is None:
            raise ValueError(
                f"configuration for {config.model_name!r} matches no "
                f"subnet level of {self._level_configs[0].model_name!r}"
            )
        if config.proper_batch_size != self.config.proper_batch_size:
            raise ValueError(
                f"hot swap must preserve the serving batch size "
                f"(engine serves {self.config.proper_batch_size}, new "
                f"configuration is for {config.proper_batch_size}); "
                "build a new engine to change batch size"
            )
        self._level_configs[target] = config
        self._pipelines.pop(target, None)
        if target == self.level:
            return super().swap_configuration(config)
        return True

    def step(self, *, force: bool = False) -> int:
        served_level = self.level    # a deferred switch lands after
        done = super().step(force=force)
        if done and served_level > 0:
            self.degraded_steps += 1
        return done

    def _drain_pending_swap(self) -> None:
        super()._drain_pending_swap()
        if self._pending_level is not None:
            k, self._pending_level = self._pending_level, None
            self._apply_level(k)
