"""Elastic BNNs: degrade width, not availability (ARCHITECTURE §15).

One trained, packed BNN yields a family of K nested-width subnets —
each narrower level a prefix *view* of the same packed bitplane
tensors (:mod:`repro.elastic.subnet`), each planned/priced through the
ordinary profile→map→fuse chain under a level-tagged store key
(:mod:`repro.elastic.planner`), all K resident behind one
:class:`ElasticEngine` that switches level at batch boundaries
(:mod:`repro.elastic.engine`).  The
:class:`~repro.fleet.router.QualityController` closes the loop:
sustained shedding hot-swaps a tenant one level narrower before more
requests die at the door; sustained headroom restores width —
honoring per-tenant ``quality_floor`` and journaling every transition.
"""

from repro.elastic.engine import ElasticEngine
from repro.elastic.planner import ElasticPlan, plan_family
from repro.elastic.subnet import (
    ElasticSpec,
    SubnetFamily,
    SubnetLevel,
    level_name,
    slice_packed,
    slice_params_fp,
)
from repro.fleet.router import QualityController, QualityRecord

__all__ = [
    "ElasticEngine",
    "ElasticPlan",
    "ElasticSpec",
    "QualityController",
    "QualityRecord",
    "SubnetFamily",
    "SubnetLevel",
    "level_name",
    "plan_family",
    "slice_packed",
    "slice_params_fp",
]
