"""One simulated serving host: a full PR-5 stack (per-tenant engines
behind a :class:`~repro.fleet.FleetRouter`, occupancy metered by a
:class:`~repro.fleet.DeviceTimeLedger`) plus the lifecycle the cluster
tier needs — ``ACTIVE`` hosts take new requests, ``DRAINING`` hosts
finish what they already admitted (bit-exact — a drain never drops or
re-routes an in-flight batch), ``RETIRED`` hosts are empty shells the
pool forgets.

Hosts in one process model machines in a cluster: each has its own
CPU+accelerator pair, so cross-host contention is zero by construction
and the cluster's makespan is the max over hosts, not the sum.
"""

from __future__ import annotations

import math
import time
from collections import deque

from repro.fleet.ledger import DeviceTimeLedger
from repro.fleet.router import FleetRouter

ACTIVE = "active"
DRAINING = "draining"
RETIRED = "retired"


class ServingHost:
    """One host of the pool.  Build engines through
    ``engine_factory(tenant_plan, config, **kwargs)`` (defaults to a
    plain :class:`~repro.serving.ServingEngine`) so benchmarks can
    inject contention-taxed engines without subclassing the host."""

    def __init__(
        self,
        host_id: int,
        *,
        engine_factory=None,
        clock=time.monotonic,
        occupancy_window: int = 16,
        engine_kwargs: dict | None = None,
    ):
        if occupancy_window < 1:
            raise ValueError("occupancy_window must be >= 1")
        self.host_id = host_id
        self.status = ACTIVE
        self.ledger = DeviceTimeLedger()
        self.router = FleetRouter(ledger=self.ledger)
        self._engine_factory = engine_factory
        self._clock = clock
        self._engine_kwargs = dict(engine_kwargs or {})
        self.occupancy_window = int(occupancy_window)
        # 1.0/0.0 per dispatch round (served work / sat idle) — the
        # windowed busy-fraction the elastic controller watches.
        # Round-windowed rather than wall-time-windowed: simulated
        # hosts share one process clock, so a host's wall window
        # includes its peers' serving time and a time-based fraction
        # would cap at 1/n_hosts even under saturation
        self._busy: deque = deque(maxlen=self.occupancy_window)
        self.tenant_plans: dict = {}   # name -> TenantPlan

    # -- tenancy -----------------------------------------------------
    def add_tenant(self, tp, config, **router_kwargs) -> None:
        """Stand up an engine for `tp` under `config` (the host-local
        jointly-mapped configuration) and register it."""
        if self.status != ACTIVE:
            raise RuntimeError(
                f"host {self.host_id} is {self.status}; cannot add "
                f"tenant {tp.name!r}"
            )
        kwargs = dict(self._engine_kwargs)
        kwargs.setdefault("allowed_batch_sizes", tp.table.batch_sizes)
        kwargs["observer"] = self.ledger.observer(tp.name)
        elastic_plan = getattr(tp, "elastic", None)
        if self._engine_factory is not None:
            engine = self._engine_factory(tp, config, **kwargs)
        elif elastic_plan is not None:
            # elastic tenant: all subnet levels resident, the joint
            # host-local mapping serving as level 0's configuration
            from repro.elastic import ElasticEngine

            engine = ElasticEngine(
                elastic_plan, config=config,
                quality_floor=getattr(tp, "quality_floor", None),
                **kwargs,
            )
        else:
            from repro.serving import ServingEngine

            engine = ServingEngine(tp.model, tp.packed, config, **kwargs)
        router_kwargs.setdefault("priority", tp.priority)
        router_kwargs.setdefault("deadline_s", tp.deadline_s)
        self.router.add_tenant(tp.name, engine, **router_kwargs)
        self.tenant_plans[tp.name] = tp

    def tenant_names(self) -> tuple:
        return tuple(self.tenant_plans)

    def hosts_tenant(self, name: str) -> bool:
        return name in self.tenant_plans

    # -- serving -----------------------------------------------------
    @property
    def accepting(self) -> bool:
        return self.status == ACTIVE

    def submit(self, tenant: str, x):
        if not self.accepting:
            raise RuntimeError(
                f"host {self.host_id} is {self.status}; dispatch must "
                "not route new requests here"
            )
        return self.router.submit(tenant, x)

    def pending(self) -> int:
        """Requests queued across every tenant on this host."""
        return sum(
            t.engine.batcher.pending() for t in self.router.tenants()
        )

    def migrate_queued(self, tenant: str, target: "ServingHost") -> int:
        """Hand `tenant`'s *queued* (admitted but not yet dispatched)
        requests to `target`'s replica of the same tenant — the drain
        hand-off path: requests an engine already popped still finish
        here (bit-exact, never re-routed mid-batch), but work nothing
        has started moves to a host that is still accepting.  Returns
        requests moved."""
        if not target.hosts_tenant(tenant):
            raise ValueError(
                f"host {target.host_id} has no replica of {tenant!r}"
            )
        src = self.router.tenant(tenant).engine.batcher
        dst = target.router.tenant(tenant).engine.batcher
        return src.migrate_to(dst)

    def step(self, *, force: bool = False) -> dict:
        """One router dispatch round, busy-metered for occupancy."""
        served = self.router.step(force=force)
        self._busy.append(1.0 if served else 0.0)
        return served

    def drain(self, *, max_steps: int = 1000) -> dict:
        """Forced steps until every queue is empty.  In-flight
        requests complete on this host's engines — draining changes
        *where new work goes*, never *how admitted work executes*."""
        total: dict = {}
        for _ in range(max_steps):
            served = self.step(force=True)
            if not served:
                break
            for name, n in served.items():
                total[name] = total.get(name, 0) + n
        return total

    # -- lifecycle ---------------------------------------------------
    def start_drain(self) -> None:
        if self.status == ACTIVE:
            self.status = DRAINING

    def retire(self) -> None:
        """Finalize a drained host.  Refuses while work is in flight:
        the drain-then-retire order is the bit-exactness guarantee."""
        if self.pending():
            raise RuntimeError(
                f"host {self.host_id} still has {self.pending()} "
                "in-flight requests; drain before retiring"
            )
        self.status = RETIRED

    # -- telemetry ---------------------------------------------------
    def occupancy(self) -> float:
        """Busy fraction over the trailing ``occupancy_window``
        dispatch rounds: 1.0 means every recent round served work, 0.0
        means the host sat idle.  A young host reads its (short)
        actual history, so a freshly-added host under load registers
        hot immediately."""
        if not self._busy:
            return 0.0
        return sum(self._busy) / len(self._busy)

    def stats(self) -> dict:
        return {
            "host_id": self.host_id,
            "status": self.status,
            "pending": self.pending(),
            "occupancy": self.occupancy(),
            "tenants": self.router.stats(),
            "ledger": self.ledger.snapshot(),
        }


def latency_quantile(samples, q: float) -> float:
    """Nearest-rank quantile (q in [0, 1]) of `samples` — the p99
    helper cluster benchmarks and isolation assertions share."""
    if not samples:
        return 0.0
    xs = sorted(samples)
    k = min(len(xs) - 1, max(0, math.ceil(q * len(xs)) - 1))
    return xs[k]
