"""Request dispatch across the host pool: which replica of a tenant
gets the next request.

Two pluggable policies (both exclude non-``ACTIVE`` hosts, so a
draining host stops receiving work the step it begins draining):

* :class:`LeastLoaded` — pick the candidate host with the fewest
  pending requests (total across tenants: a host busy with *someone*
  is busy for *everyone* — both processors are shared).  Ties break
  toward the lower host id, keeping dispatch deterministic.
* :class:`ConsistentHash` — a virtual-node hash ring per tenant.
  Requests carrying the same affinity ``key`` land on the same host
  while the pool is stable, and only ~1/N of keys move when a host
  joins or retires — the property that makes elastic scaling cheap
  for cache-warm tenants.

Policies see candidate hosts already filtered to those hosting the
tenant; they only choose among replicas.
"""

from __future__ import annotations

import bisect
import hashlib
from typing import Sequence


def _ring_hash(token: str) -> int:
    # stable across processes (unlike hash()) — a ring that reshuffles
    # per run would defeat key affinity
    return int.from_bytes(
        hashlib.blake2b(token.encode(), digest_size=8).digest(), "big"
    )


class LeastLoaded:
    """Route to the candidate with the shortest total queue."""

    name = "least_loaded"

    def choose(self, hosts: Sequence, tenant: str, key=None):
        if not hosts:
            raise LookupError(f"no active host serves tenant {tenant!r}")
        return min(hosts, key=lambda h: (h.pending(), h.host_id))


class ConsistentHash:
    """Key-affinity routing on a virtual-node ring.

    ``replicas`` virtual nodes per host smooth the ring (a plain
    one-node-per-host ring gives some host 3x its share of key
    space).  ``key=None`` falls back to least-loaded — affinity with
    no key is meaningless, and dropping the request on host 0 would
    make keyless tenants a hot spot."""

    name = "consistent_hash"

    def __init__(self, *, replicas: int = 32):
        if replicas < 1:
            raise ValueError("replicas must be >= 1")
        self.replicas = replicas
        self._fallback = LeastLoaded()

    def choose(self, hosts: Sequence, tenant: str, key=None):
        if not hosts:
            raise LookupError(f"no active host serves tenant {tenant!r}")
        if key is None:
            return self._fallback.choose(hosts, tenant)
        ring = []   # (point, host), sorted — rebuilt per call so the
        # ring always reflects the live pool; pools are a handful of
        # hosts, and correctness-under-churn beats caching here
        for h in hosts:
            for r in range(self.replicas):
                ring.append((_ring_hash(f"{h.host_id}:{r}"), h))
        ring.sort(key=lambda p: p[0])
        point = _ring_hash(f"{tenant}:{key}")
        i = bisect.bisect_right([p for p, _ in ring], point)
        return ring[i % len(ring)][1]


POLICIES = {
    LeastLoaded.name: LeastLoaded,
    ConsistentHash.name: ConsistentHash,
}


def make_policy(policy):
    """Resolve a routing policy: an instance passes through, a name
    (``"least_loaded"`` / ``"consistent_hash"``) constructs one."""
    if hasattr(policy, "choose"):
        return policy
    try:
        return POLICIES[policy]()
    except KeyError:
        raise ValueError(
            f"unknown routing policy {policy!r}; have "
            f"{sorted(POLICIES)}"
        ) from None
