"""Elastic host-pool control: grow on sustained high occupancy,
drain-then-retire on sustained low, journal every decision.

This promotes the vestigial ``repro.runtime.elastic`` seed (device
re-meshing after pool-size changes — re-exported here as
:func:`remesh_state`, the state-migration hook for tenants whose
parameters are sharded across a host's devices) into a real control
loop over the serving cluster:

* the controller watches each host's **windowed occupancy** (busy
  fraction of its recent dispatch rounds — the host-level roll-up of
  what the device-time ledger meters per tenant);
* mean occupancy >= ``high_water`` for ``sustain`` consecutive
  observations → **degrade width** when the cluster hosts elastic
  tenants with floor room (``repro.elastic`` — a narrower subnet is a
  batch-boundary swap, far cheaper than a host), else **scale up**
  (add a host, replicate the hottest host's tenants onto it);
  symmetrically, low water restores degraded width before it drains
  a host;
* mean occupancy <= ``low_water`` for ``sustain`` observations →
  **drain** the emptiest host: it stops accepting requests, finishes
  its in-flight batches bit-exact, and only then **retires**;
* while any host is draining, a newly-triggered decision is
  **deferred** — journaled but not acted on — mirroring the serving
  engine's deferred-swap semantics (never two topology changes in
  flight at once).

Every decision (including deferrals) appends a :class:`ScaleRecord`
to the controller's journal, the cluster-level analogue of the adapt
loop's ``SwapRecord``: scaling that can't explain itself can't be
trusted in a latency postmortem.
"""

from __future__ import annotations

import dataclasses
import time

from repro.runtime.elastic import remesh_state  # noqa: F401  (promoted seed)

from repro.cluster.host import ACTIVE, DRAINING

__all__ = ["ElasticController", "ScaleRecord", "remesh_state"]


@dataclasses.dataclass(frozen=True)
class ScaleRecord:
    """One journaled scaling decision."""

    seq: int                     # decision number, monotonically increasing
    at_s: float                  # controller clock at decision time
    action: str                  # scale_up | drain | retire | deferred
    reason: str                  # human-readable trigger
    occupancy: dict              # host_id -> windowed busy fraction
    n_active_before: int
    n_active_after: int
    moved_tenants: tuple = ()    # tenants (re)placed by this action

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["moved_tenants"] = list(self.moved_tenants)
        return d


class ElasticController:
    """Watches a :class:`~repro.cluster.Cluster`'s host pool and
    issues grow/shrink decisions.  Drive it by calling
    :meth:`observe` once per serving tick (the cluster's ``step``
    does this when the controller is attached)."""

    def __init__(
        self,
        *,
        high_water: float = 0.75,
        low_water: float = 0.15,
        sustain: int = 3,
        min_hosts: int = 1,
        max_hosts: int = 8,
        clock=time.monotonic,
    ):
        if not 0.0 <= low_water < high_water <= 1.0:
            raise ValueError(
                "need 0 <= low_water < high_water <= 1, got "
                f"low={low_water} high={high_water}"
            )
        if sustain < 1:
            raise ValueError("sustain must be >= 1")
        if not 1 <= min_hosts <= max_hosts:
            raise ValueError("need 1 <= min_hosts <= max_hosts")
        self.high_water = high_water
        self.low_water = low_water
        self.sustain = sustain
        self.min_hosts = min_hosts
        self.max_hosts = max_hosts
        self._clock = clock
        self._hi_streak = 0
        self._lo_streak = 0
        self.journal: list = []

    # -- journaling --------------------------------------------------
    def _record(
        self, action, reason, occ, before, after, moved=()
    ) -> ScaleRecord:
        rec = ScaleRecord(
            seq=len(self.journal), at_s=self._clock(), action=action,
            reason=reason, occupancy=dict(occ),
            n_active_before=before, n_active_after=after,
            moved_tenants=tuple(moved),
        )
        self.journal.append(rec)
        return rec

    # -- control loop ------------------------------------------------
    def observe(self, cluster) -> ScaleRecord | None:
        """One control tick.  Retires finished drains first (that
        completes the previous decision), then evaluates the water
        marks.  Returns the journal entry when anything happened —
        including a deferral — else ``None``."""
        active = [h for h in cluster.hosts if h.status == ACTIVE]
        draining = [h for h in cluster.hosts if h.status == DRAINING]
        occ = {h.host_id: h.occupancy() for h in active}

        # 1) complete an in-flight drain: retire once empty
        for h in draining:
            if h.pending() == 0:
                h.retire()
                cluster.on_retired(h)
                return self._record(
                    "retire",
                    f"host {h.host_id} drained empty",
                    occ, len(active), len(active),
                )

        mean_occ = (
            sum(occ.values()) / len(occ) if occ else 0.0
        )
        self._hi_streak = (
            self._hi_streak + 1 if mean_occ >= self.high_water else 0
        )
        self._lo_streak = (
            self._lo_streak + 1 if mean_occ <= self.low_water else 0
        )

        want_up = (
            self._hi_streak >= self.sustain
            and len(active) < self.max_hosts
        )
        want_down = (
            self._lo_streak >= self.sustain
            and len(active) > self.min_hosts
        )
        if not (want_up or want_down):
            return None

        # 2) one topology change in flight at a time: a triggered
        # decision during a drain is journaled, not acted on (the
        # streak keeps building, so it fires on the next clear tick)
        if draining:
            return self._record(
                "deferred",
                f"{'scale_up' if want_up else 'drain'} triggered at "
                f"mean occupancy {mean_occ:.2f} while host "
                f"{draining[0].host_id} is draining",
                occ, len(active), len(active),
            )

        if want_up:
            self._hi_streak = 0
            # degrading an elastic tenant's width is cheaper than a
            # host: prefer it whenever a quality floor leaves room
            # (repro.elastic; a narrower subnet swap is a batch
            # boundary, a new host is a topology change)
            degraded = getattr(cluster, "degrade_width", lambda: ())()
            if degraded:
                return self._record(
                    "degrade_width",
                    f"mean occupancy {mean_occ:.2f} >= "
                    f"{self.high_water} for {self.sustain} ticks; "
                    "narrowed elastic tenants instead of adding a host",
                    occ, len(active), len(active), degraded,
                )
            host, moved = cluster.scale_up()
            return self._record(
                "scale_up",
                f"mean occupancy {mean_occ:.2f} >= "
                f"{self.high_water} for {self.sustain} ticks",
                occ, len(active), len(active) + 1, moved,
            )

        self._lo_streak = 0
        # headroom pays back quality debt before it removes capacity:
        # restore degraded widths first, shrink the pool only once
        # every elastic tenant is back at full width
        restored = getattr(cluster, "restore_width", lambda: ())()
        if restored:
            return self._record(
                "restore_width",
                f"mean occupancy {mean_occ:.2f} <= {self.low_water} "
                f"for {self.sustain} ticks; restored elastic tenant "
                "width before shrinking the pool",
                occ, len(active), len(active), restored,
            )
        victim = min(active, key=lambda h: (h.occupancy(), -h.host_id))
        moved = cluster.start_drain(victim)
        return self._record(
            "drain",
            f"mean occupancy {mean_occ:.2f} <= {self.low_water} "
            f"for {self.sustain} ticks; draining host "
            f"{victim.host_id}",
            occ, len(active), len(active) - 1, moved,
        )
