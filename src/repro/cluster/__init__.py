"""Multi-host cluster serving tier (docs/ARCHITECTURE.md §13).

Scales the single-host co-serving stack (``repro.fleet``) out to a
pool of simulated hosts: contention-priced tenant placement
(:mod:`~repro.cluster.placement`), per-host routers and ledgers
(:mod:`~repro.cluster.host`), pluggable request dispatch
(:mod:`~repro.cluster.dispatch`), and an elastic pool controller with
a journaled decision trail (:mod:`~repro.cluster.elastic`).

Most consumers should reach this through ``repro.api.Deployment.plan(
models, hosts=N)`` rather than constructing a :class:`Cluster`
directly.
"""

from repro.cluster.cluster import Cluster
from repro.cluster.dispatch import (
    ConsistentHash,
    LeastLoaded,
    make_policy,
)
from repro.cluster.elastic import ElasticController, ScaleRecord, remesh_state
from repro.cluster.host import (
    ACTIVE,
    DRAINING,
    RETIRED,
    ServingHost,
    latency_quantile,
)
from repro.cluster.placement import (
    ClusterPlan,
    HostAssignment,
    place_tenants,
)
