"""Tenant-to-host placement: bin-pack tenants onto N simulated hosts
under the same contention model ``map_fleet`` prices with.

Each host is one heterogeneous CPU+accelerator machine running its own
PR-5 serving stack (``FleetRouter`` + ``DeviceTimeLedger``).  A
tenant's *demand* is its ``placement_shares()`` profile — the fraction
of per-example work it asks of each processor — weighted by its
relative request rate.  Placement is the classic decreasing-demand
greedy bin-pack, except the "bin level" is not a scalar: a candidate
host's cost is the contention-priced :func:`repro.fleet.scheduler.
joint_makespan` of its resident tenants plus the candidate, so two
device-heavy tenants repel each other onto different hosts while a
host-heavy and a device-heavy tenant pack together cheaply (they
contend on different processors).

After assignment every host's resident set is jointly mapped with
:func:`map_fleet` — placement decides *who shares a machine*, the
fleet mapper decides *how each machine splits its layers* given the
co-residents placement chose.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.fleet.scheduler import FleetPlan, joint_makespan, map_fleet


@dataclasses.dataclass(frozen=True)
class HostAssignment:
    """One host's slice of a :class:`ClusterPlan`."""

    host_id: int
    tenant_names: tuple
    # contention-priced makespan of the resident set (the bin level
    # the packer minimized), and the host's joint fleet mapping
    priced_makespan_s: float
    fleet_plan: FleetPlan

    def to_dict(self) -> dict:
        return {
            "host_id": self.host_id,
            "tenants": list(self.tenant_names),
            "priced_makespan_s": self.priced_makespan_s,
            "joint_makespan_s": self.fleet_plan.joint_makespan_s,
        }


@dataclasses.dataclass(frozen=True)
class ClusterPlan:
    """The full placement: who lives where, at what priced cost."""

    assignments: tuple            # HostAssignment per host, id order

    @property
    def n_hosts(self) -> int:
        return len(self.assignments)

    def host_of(self, tenant: str) -> int:
        for a in self.assignments:
            if tenant in a.tenant_names:
                return a.host_id
        raise KeyError(tenant)

    def config_of(self, tenant: str):
        """The tenant's jointly-mapped configuration on its host."""
        a = self.assignments[self.host_of(tenant)]
        i = a.tenant_names.index(tenant)
        return a.fleet_plan.tenants[i].config

    @property
    def makespan_s(self) -> float:
        """Cluster makespan: hosts run in parallel, so the cluster is
        as slow as its slowest host."""
        return max(
            (a.fleet_plan.joint_makespan_s for a in self.assignments
             if a.tenant_names),
            default=0.0,
        )

    def to_dict(self) -> dict:
        return {
            "n_hosts": self.n_hosts,
            "makespan_s": self.makespan_s,
            "hosts": [a.to_dict() for a in self.assignments],
        }


def _demand(tp) -> float:
    """Scalar demand for sort order: weighted per-example time."""
    return tp.weight * tp.config.expected_time_per_example


def place_tenants(
    tenants: Sequence,
    n_hosts: int,
    *,
    gamma: float = 1.0,
    law=None,
    policy: str = "dp",
    configs: Sequence[str] | None = None,
    batch_sizes: Sequence[int] | None = None,
    registry=None,
) -> ClusterPlan:
    """Assign `tenants` (``repro.api.TenantPlan``-like: ``.name``,
    ``.table``, ``.config``, ``.weight``) to `n_hosts` hosts.

    Decreasing-demand greedy: heaviest tenant first, each placed on
    the host whose priced joint makespan grows least.  Ties (e.g. all
    empty hosts at the start) break toward the lower host id, so the
    packing is deterministic.  Hosts left empty stay in the plan with
    an empty resident set — the elastic controller retires them.
    """
    if n_hosts < 1:
        raise ValueError("n_hosts must be >= 1")
    order = sorted(tenants, key=_demand, reverse=True)
    residents: list = [[] for _ in range(n_hosts)]

    for tp in order:
        best_host, best_cost = 0, math.inf
        for h in range(n_hosts):
            trial = residents[h] + [tp]
            cost = joint_makespan(
                [t.table for t in trial],
                [t.config for t in trial],
                gamma=gamma, law=law,
                weights=[t.weight for t in trial],
                registry=registry,
            )
            if cost < best_cost - 1e-12:
                best_host, best_cost = h, cost
        residents[best_host].append(tp)

    assignments = []
    for h in range(n_hosts):
        group = residents[h]
        names = tuple(t.name for t in group)
        if group:
            plan = map_fleet(
                [t.table for t in group],
                names=names, policy=policy, configs=configs,
                batch_sizes=batch_sizes,
                weights=[t.weight for t in group],
                gamma=gamma, law=law, registry=registry,
            )
            priced = joint_makespan(
                [t.table for t in group], list(plan.configs),
                gamma=gamma, law=law,
                weights=[t.weight for t in group], registry=registry,
            )
        else:
            plan = FleetPlan(
                tenants=(), joint_makespan_s=0.0,
                baseline_makespan_s=0.0, rounds=0, converged=True,
            )
            priced = 0.0
        assignments.append(HostAssignment(
            host_id=h, tenant_names=names,
            priced_makespan_s=priced, fleet_plan=plan,
        ))
    return ClusterPlan(assignments=tuple(assignments))
