"""The cluster orchestrator: placement, dispatch, serving, scaling.

:class:`Cluster` is what ``repro.api.Deployment`` stands up for
``hosts > 1``.  It owns the pool of :class:`~repro.cluster.host.
ServingHost`\\ s, places tenants with :func:`~repro.cluster.placement.
place_tenants`, routes requests through a pluggable dispatch policy,
and (optionally) lets an :class:`~repro.cluster.elastic.
ElasticController` grow and shrink the pool.

Re-planning invariant: every engine in the cluster serves the same
proper batch size (placement maps with one ``batch_sizes`` entry), so
topology changes that re-map a host's residents can apply with the
engine's batch-boundary **hot swap** — a scale event never rebuilds a
live engine, and every in-flight request completes under exactly one
configuration.

With a shared ``store`` (any :class:`~repro.store.ProfileStore`
backend — typically ``sqlite://`` so every host reads one file), the
cluster persists each host's jointly-mapped configurations under that
co-tenancy's :func:`~repro.store.fleet_scope`, and scale events
**warm-start from the cache**: a replication whose exact resident
group was mapped before loads the stored configurations instead of
re-running the joint mapper (``cache_hits``/``cache_misses`` count
the outcomes).
"""

from __future__ import annotations

import time
from typing import Sequence

from repro.cluster.dispatch import make_policy
from repro.cluster.elastic import ElasticController
from repro.cluster.host import ACTIVE, RETIRED, ServingHost
from repro.cluster.placement import place_tenants
from repro.fleet.scheduler import map_fleet
from repro.store import ProfileStore, fleet_scope


class Cluster:
    def __init__(
        self,
        tenant_plans: Sequence,
        *,
        n_hosts: int = 2,
        gamma: float = 1.0,
        law=None,
        policy=None,
        mapping_policy: str = "dp",
        configs: Sequence[str] | None = None,
        batch_sizes: Sequence[int] | None = None,
        registry=None,
        engine_factory=None,
        elastic=None,
        clock=time.monotonic,
        occupancy_window: int = 16,
        engine_kwargs: dict | None = None,
        store=None,
    ):
        """`tenant_plans` are ``repro.api.TenantPlan``-like bundles
        (model, packed params, profile table, solo configuration).
        `elastic` is ``None`` (fixed pool), an
        :class:`ElasticController`, or a dict of its knobs.  `store`
        is an optional shared :class:`~repro.store.ProfileStore` (or
        backend URI) all hosts read mappings through (module
        docstring)."""
        self.tenants = {tp.name: tp for tp in tenant_plans}
        if len(self.tenants) != len(tenant_plans):
            raise ValueError("tenant names must be unique")
        self._gamma = gamma
        self._law = law
        self._mapping_policy = mapping_policy
        self._configs = configs
        self._batch_sizes = (
            tuple(batch_sizes) if batch_sizes is not None else None
        )
        self._registry = registry
        self._engine_factory = engine_factory
        self._clock = clock
        self._occupancy_window = occupancy_window
        self._engine_kwargs = dict(engine_kwargs or {})
        self.policy = make_policy(policy if policy is not None
                                  else "least_loaded")
        if isinstance(elastic, dict):
            elastic = ElasticController(clock=clock, **elastic)
        self.elastic = elastic
        if store is not None and not isinstance(store, ProfileStore):
            store = ProfileStore(store)
        self.store = store
        self.cache_hits = 0
        self.cache_misses = 0

        self.plan = place_tenants(
            tenant_plans, n_hosts, gamma=gamma, law=law,
            policy=mapping_policy, configs=configs,
            batch_sizes=self._batch_sizes, registry=registry,
        )
        self.hosts: list = []
        for a in self.plan.assignments:
            host = self._new_host()
            for name in a.tenant_names:
                host.add_tenant(
                    self.tenants[name], self.plan.config_of(name)
                )
            # seed the shared cache with this co-tenancy's joint
            # mappings, so a later scale-up replicating the same
            # resident group warm-starts instead of re-mapping
            if self.store is not None and a.tenant_names:
                self._save_group(
                    {
                        name: self.plan.config_of(name)
                        for name in a.tenant_names
                    }
                )

    # -- shared-cache plumbing ----------------------------------------
    def _group_store(self, names) -> "ProfileStore":
        return self.store.with_scope(fleet_scope(names))

    def _save_group(self, configs_by_name: dict) -> None:
        scoped = self._group_store(tuple(configs_by_name))
        for config in configs_by_name.values():
            scoped.save_mapping(config)

    def _load_group(self, group) -> dict | None:
        """The cached jointly-mapped configurations for exactly this
        resident group, or None unless *every* member has a stored
        mapping that matches its table and the cluster's one serving
        batch size (the hot-swap invariant)."""
        from repro.store import signature_from_labels

        scoped = self._group_store([t.name for t in group])
        out = {}
        for t in group:
            config = scoped.load_mapping_for_labels(
                signature_from_labels(
                    t.table.model_name, t.table.layer_labels
                ),
                policy=self._mapping_policy,
            )
            if (
                config is None
                or config.layer_labels != t.table.layer_labels
                or config.proper_batch_size
                != t.config.proper_batch_size
            ):
                return None
            out[t.name] = config
        return out

    # -- pool plumbing -----------------------------------------------
    def _new_host(self) -> ServingHost:
        host = ServingHost(
            len(self.hosts),
            engine_factory=self._engine_factory,
            clock=self._clock,
            occupancy_window=self._occupancy_window,
            engine_kwargs=self._engine_kwargs,
        )
        self.hosts.append(host)
        return host

    def active_hosts(self) -> list:
        return [h for h in self.hosts if h.status == ACTIVE]

    def _hosts_for(self, tenant: str) -> list:
        return [
            h for h in self.hosts
            if h.accepting and h.hosts_tenant(tenant)
        ]

    def _replicate(self, tp, host: ServingHost) -> None:
        """Add tenant `tp` to `host`, re-mapping the host's resident
        set jointly so existing residents' configurations account for
        their new co-runner.  Residents whose mapping changed are
        batch-boundary hot-swapped (same serving batch size by the
        cluster invariant), never rebuilt.

        With a shared store, a resident group that was jointly mapped
        before (any host, any process over the same backend) loads its
        configurations from the cache instead of re-running the
        mapper; a miss maps and writes back, so the next identical
        scale event hits."""
        group = [self.tenants[n] for n in host.tenant_names()] + [tp]
        by_name = None
        if self.store is not None:
            by_name = self._load_group(group)
            if by_name is not None:
                self.cache_hits += 1
            else:
                self.cache_misses += 1
        if by_name is None:
            plan = map_fleet(
                [t.table for t in group],
                names=[t.name for t in group],
                policy=self._mapping_policy, configs=self._configs,
                batch_sizes=self._batch_sizes,
                weights=[t.weight for t in group],
                gamma=self._gamma, law=self._law,
                registry=self._registry,
            )
            by_name = {t.name: t.config for t in plan.tenants}
            if self.store is not None:
                self._save_group(by_name)
        for name in host.tenant_names():
            engine = host.router.tenant(name).engine
            new = by_name[name]
            # elastic engines route the swap to their full-width slot
            # (a degraded tenant keeps its current level); compare
            # against that slot, not whatever level is serving
            current = (
                engine.level_config(0)
                if hasattr(engine, "level_config") else engine.config
            )
            if new.layer_configs != current.layer_configs:
                engine.swap_configuration(new)
        host.add_tenant(tp, by_name[tp.name])

    # -- scaling hooks (called by ElasticController) -------------------
    def degrade_width(self) -> tuple:
        """Narrow every elastic engine with quality-floor room by one
        subnet level (``repro.elastic``) — the controller's preferred
        move under high water: a width swap is a batch boundary, a new
        host is a topology change.  Returns descriptors of the
        engines narrowed (``tenant@h{id}:L{level}``), empty when no
        floor permits."""
        moved = []
        for h in self.active_hosts():
            for t in h.router.tenants():
                e = t.engine
                if hasattr(e, "set_level") and e.can_degrade():
                    target = e.level + 1
                    e.set_level(target)
                    moved.append(f"{t.name}@h{h.host_id}:L{target}")
        return tuple(moved)

    def restore_width(self) -> tuple:
        """Widen every degraded elastic engine by one subnet level —
        the controller's preferred move under low water: quality debt
        is paid back before capacity is removed.  Returns descriptors
        of the engines widened, empty when none are degraded."""
        moved = []
        for h in self.active_hosts():
            for t in h.router.tenants():
                e = t.engine
                if hasattr(e, "set_level") and e.can_restore():
                    target = e.level - 1
                    e.set_level(target)
                    moved.append(f"{t.name}@h{h.host_id}:L{target}")
        return tuple(moved)

    def scale_up(self) -> tuple:
        """Add a host and replicate the hottest host's residents onto
        it, splitting that host's load.  Returns (host, moved)."""
        donors = self.active_hosts()
        hottest = max(
            donors, key=lambda h: (h.occupancy(), h.pending())
        )
        host = self._new_host()
        moved = []
        for name in hottest.tenant_names():
            self._replicate(self.tenants[name], host)
            moved.append(name)
        if not moved:
            # hottest host was empty (degenerate pool) — replicate
            # every tenant so the new host is immediately useful
            for name, tp in self.tenants.items():
                self._replicate(tp, host)
                moved.append(name)
        return host, tuple(moved)

    def start_drain(self, host: ServingHost) -> tuple:
        """Begin draining `host`.  Tenants whose only accepting
        replica lives there are first replicated onto the least-loaded
        remaining host, so no tenant loses service while the drain
        completes; then every tenant's *queued* (not-yet-dispatched)
        requests migrate to an accepting replica — the draining host
        finishes only what its engines already popped, instead of
        slowly serving a backlog no new capacity can help with.
        Returns the moved tenant names."""
        moved = []
        remaining = [h for h in self.active_hosts() if h is not host]
        if not remaining:
            raise RuntimeError("cannot drain the last active host")
        host.start_drain()
        for name in host.tenant_names():
            if not self._hosts_for(name):
                target = min(
                    remaining, key=lambda h: (h.pending(), h.host_id)
                )
                self._replicate(self.tenants[name], target)
                moved.append(name)
        # hand off the queued backlog (dispatched batches stay — they
        # complete bit-exact on the engines that popped them)
        for name in host.tenant_names():
            replicas = self._hosts_for(name)
            if not replicas:
                continue
            target = min(
                replicas, key=lambda h: (h.pending(), h.host_id)
            )
            host.migrate_queued(name, target)
        return tuple(moved)

    def on_retired(self, host: ServingHost) -> None:
        """Post-retire hook (journaled by the controller)."""

    # -- serving -----------------------------------------------------
    def submit(self, tenant: str, x, *, key=None):
        """Route one request to a replica of `tenant` (dispatch
        policy picks among accepting hosts)."""
        if tenant not in self.tenants:
            raise KeyError(f"unknown tenant {tenant!r}")
        host = self.policy.choose(self._hosts_for(tenant), tenant, key)
        return host.submit(tenant, x)

    def step(self, *, force: bool = False) -> dict:
        """One cluster tick: every non-retired host takes a dispatch
        round, then the elastic controller (when attached) takes a
        control tick.  Returns {tenant: served} aggregated."""
        served: dict = {}
        for h in self.hosts:
            if h.status == RETIRED:
                continue
            for name, n in h.step(force=force).items():
                served[name] = served.get(name, 0) + n
        if self.elastic is not None:
            self.elastic.observe(self)
        return served

    def drain(self, *, max_steps: int = 1000) -> dict:
        """Force-serve until every host's queues are empty."""
        total: dict = {}
        for h in self.hosts:
            if h.status == RETIRED:
                continue
            for name, n in h.drain(max_steps=max_steps).items():
                total[name] = total.get(name, 0) + n
        return total

    def pending(self) -> int:
        return sum(
            h.pending() for h in self.hosts if h.status != RETIRED
        )

    def stats(self) -> dict:
        out = {
            "mode": "cluster",
            "n_hosts": len(self.hosts),
            "n_active": len(self.active_hosts()),
            "plan": self.plan.to_dict(),
            "hosts": [h.stats() for h in self.hosts],
        }
        if self.elastic is not None:
            out["elastic"] = [
                r.to_dict() for r in self.elastic.journal
            ]
        if self.store is not None:
            out["cache"] = {
                "hits": self.cache_hits,
                "misses": self.cache_misses,
                "backend": self.store.stats(),
            }
        return out
