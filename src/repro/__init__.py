"""repro: HEP-BNN on TPU.

A JAX framework implementing the HEP-BNN paper's profiling-driven
per-layer execution-configuration search, with a BNN substrate
(bit-packed xnor/popcount inference, STE training), Pallas TPU kernels
parameterized by the paper's X/Y/Z parallelism aspects, and a multi-pod
LM substrate where the same greedy mapper selects per-layer sharding
schemes (HEP-Shard).
"""

__version__ = "1.0.0"
