"""Checkpointing built for restart-resilience on shared filesystems.

* **Atomic**: write to ``step_N.tmp-<pid>`` then ``os.replace`` — a
  crash mid-write can never corrupt the latest valid checkpoint.
* **Self-validating**: payload carries a manifest (tree structure,
  shapes, dtypes) + per-file checksum; restore verifies before use.
* **Keep-N GC** and ``latest_step`` discovery for restart-from-latest.
* **Async**: ``CheckpointManager(async_save=True)`` hands serialization
  to a background thread (double-buffered host copy first, so training
  can donate/overwrite device buffers immediately).
* **Sharding-aware**: arrays are gathered to host as numpy (single-
  process here); on a real multi-host pod each host would write its
  addressable shards — the file format already namespaces by leaf path
  so that extension is additive.

Format: one ``.npz``-style msgpack-framed file per checkpoint with a
JSON manifest; no pickle (robust across refactors, no code execution).
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _tree_paths(tree: Any) -> list:
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, _ in paths:
        out.append(
            "/".join(
                str(getattr(p, "key", getattr(p, "idx", p))) for p in path
            )
        )
    return out


def save_checkpoint(directory: str | Path, step: int, tree: Any) -> Path:
    """Atomically persist a pytree of arrays under `directory/step_N`."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step}"
    tmp = directory / f"step_{step}.tmp-{os.getpid()}"
    tmp.mkdir(parents=True, exist_ok=True)

    leaves, _ = _flatten(tree)
    names = _tree_paths(tree)
    manifest = {"step": step, "leaves": []}
    arrays = {}
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        key = f"a{i}"
        arrays[key] = arr
        manifest["leaves"].append(
            {
                "path": name,
                "key": key,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "sum": hashlib.sha1(arr.tobytes()).hexdigest()[:16],
            }
        )
    np.savez(tmp / "arrays.npz", **arrays)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():  # crashed mid-GC previously; replace
        import shutil

        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def restore_checkpoint(
    directory: str | Path, step: int, like: Any, *, strict: bool = True
) -> Any:
    """Restore into the structure of `like` (arrays or
    ShapeDtypeStructs). Verifies checksums and shapes."""
    directory = Path(directory)
    path = directory / f"step_{step}"
    manifest = json.loads((path / "manifest.json").read_text())
    with np.load(path / "arrays.npz") as z:
        arrays = {k: z[k] for k in z.files}

    leaves, treedef = _flatten(like)
    names = _tree_paths(like)
    by_path = {m["path"]: m for m in manifest["leaves"]}
    out = []
    for name, leaf in zip(names, leaves):
        if name not in by_path:
            if strict:
                raise KeyError(f"checkpoint missing leaf {name}")
            out.append(leaf)
            continue
        m = by_path[name]
        arr = arrays[m["key"]]
        if strict:
            got = hashlib.sha1(arr.tobytes()).hexdigest()[:16]
            if got != m["sum"]:
                raise ValueError(f"checksum mismatch for {name}")
            if list(arr.shape) != list(leaf.shape):
                raise ValueError(
                    f"shape mismatch for {name}: "
                    f"{arr.shape} vs {leaf.shape}"
                )
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def latest_step(directory: str | Path) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [
        int(m.group(1))
        for p in directory.iterdir()
        if (m := _STEP_RE.match(p.name)) and (p / "manifest.json").exists()
    ]
    return max(steps) if steps else None


class CheckpointManager:
    """save-every-k + keep-N + optional async writer."""

    def __init__(
        self,
        directory: str | Path,
        *,
        save_every: int = 100,
        keep: int = 3,
        async_save: bool = False,
    ):
        self.directory = Path(directory)
        self.save_every = save_every
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_every == 0

    def save(self, step: int, tree: Any, *, force: bool = False):
        if not (force or self.should_save(step)):
            return
        # host copy now so donated device buffers can be reused
        host = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._save_and_gc, args=(step, host), daemon=True
            )
            self._thread.start()
        else:
            self._save_and_gc(step, host)

    def _save_and_gc(self, step: int, host_tree: Any):
        save_checkpoint(self.directory, step, host_tree)
        self._gc()

    def _gc(self):
        steps = sorted(
            int(m.group(1))
            for p in self.directory.iterdir()
            if (m := _STEP_RE.match(p.name))
        )
        import shutil

        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.directory / f"step_{s}", ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, like: Any):
        self.wait()
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return step, restore_checkpoint(self.directory, step, like)
