"""Fault-tolerant checkpointing: atomic writes, keep-N GC, exact
resume, async save."""

from repro.ckpt.checkpoint import (
    save_checkpoint,
    restore_checkpoint,
    latest_step,
    CheckpointManager,
)
