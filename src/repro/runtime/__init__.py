"""Runtime: fault-tolerant training loop, watchdog, elastic re-mesh."""

from repro.runtime.loop import TrainLoop, LoopConfig
from repro.runtime.elastic import remesh_state
