"""Elastic re-meshing: continue training after losing (or gaining)
devices — e.g. one pod of the 2x16x16 production mesh drops out.

Procedure (the standard elastic-recovery path):
  1. gather the latest checkpoint to host (already host-side numpy),
  2. build a new mesh over the surviving devices,
  3. recompute the sharding plan for the SAME ShardScheme against the
     new mesh (all divisibility guards re-evaluate automatically),
  4. device_put every leaf with its new sharding and re-jit the step.

Degraded-batch policy: keep the global batch (more per-device memory)
or scale it with the device count (keep per-device shape, changes
optimization) — exposed as `batch_policy`.

The serving-side elastic control loop lives in
:mod:`repro.cluster.elastic`, which re-exports :func:`remesh_state`
as the state-migration hook for pool-size changes.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh

from repro.models.config import ModelConfig
from repro.parallel.sharding import ShardScheme, make_param_shardings


def remesh_state(
    cfg: ModelConfig,
    state: Any,
    new_mesh: Mesh,
    scheme: Optional[ShardScheme] = None,
) -> Any:
    """Reshard a params-like pytree onto `new_mesh`."""
    shardings = make_param_shardings(cfg, new_mesh, state, scheme)
    return jax.tree.map(
        lambda leaf, sh: jax.device_put(np.asarray(leaf), sh),
        state, shardings,
    )
