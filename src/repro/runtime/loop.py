"""Fault-tolerant training loop.

Contract (restart-anywhere):
  * data batches are a pure function of (seed, step) — restart replays
    nothing and skips nothing (repro.data.loader),
  * checkpoints are atomic and self-validating (repro.ckpt),
  * the loop always begins by restoring the latest valid checkpoint,
    so crash -> relaunch converges to exactly-once step semantics,
  * a watchdog flags straggling steps (wall-time > k x EMA); on a real
    multi-host deployment the flag triggers the controller's
    replace-and-restart path — here it is surfaced in metrics and via
    an optional callback.

Failure injection: ``inject_failure_at`` raises mid-run (between a
step's commit and the next checkpoint) — tests use it to prove
recovery resumes with identical state and loss trajectory.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.ckpt import CheckpointManager


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: str
    save_every: int = 50
    keep: int = 3
    async_save: bool = False
    straggler_factor: float = 3.0
    ema_alpha: float = 0.2
    inject_failure_at: Optional[int] = None


class TrainLoop:
    """step_fn(state, batch) -> (state, metrics); state is any pytree
    (e.g. (params, opt_state, step-invariant extras))."""

    def __init__(
        self,
        step_fn: Callable,
        batch_fn: Callable[[int], Any],
        state: Any,
        cfg: LoopConfig,
        *,
        on_straggler: Optional[Callable[[int, float], None]] = None,
    ):
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.state = state
        self.cfg = cfg
        self.on_straggler = on_straggler
        self.mgr = CheckpointManager(
            cfg.ckpt_dir, save_every=cfg.save_every, keep=cfg.keep,
            async_save=cfg.async_save,
        )
        self.start_step = 0
        self.metrics_log: list = []

    def restore_if_available(self):
        step, restored = self.mgr.restore_latest(self.state)
        if step is not None:
            self.state = jax.tree.map(
                lambda like, arr: jax.device_put(np.asarray(arr)),
                self.state, restored,
            )
            self.start_step = step
        return self.start_step

    def run(self) -> dict:
        cfg = self.cfg
        self.restore_if_available()
        ema = None
        for step in range(self.start_step, cfg.total_steps):
            t0 = time.perf_counter()
            batch = self.batch_fn(step)
            self.state, metrics = self.step_fn(self.state, batch)
            jax.block_until_ready(metrics)
            dt = time.perf_counter() - t0

            straggle = False
            if ema is not None and dt > cfg.straggler_factor * ema:
                straggle = True
                if self.on_straggler:
                    self.on_straggler(step, dt)
            ema = dt if ema is None else (
                (1 - cfg.ema_alpha) * ema + cfg.ema_alpha * dt
            )

            rec = {
                "step": step + 1,
                "sec": dt,
                "straggler": straggle,
                **{k: float(v) for k, v in metrics.items()},
            }
            self.metrics_log.append(rec)

            done = step + 1
            self.mgr.save(done, self.state)
            if cfg.inject_failure_at is not None and done == cfg.inject_failure_at:
                raise InjectedFailure(f"injected failure after step {done}")
        self.mgr.save(cfg.total_steps, self.state, force=True)
        self.mgr.wait()
        return {
            "final_step": cfg.total_steps,
            "metrics": self.metrics_log,
        }
