"""Open kernel-variant registry — the searchable per-layer GEMM space.

The paper fixes 8 implementations per layer (CPU + 7 aspect configs).
Larq-CE-style engines show that the real cost surface is wider: tiling,
packing and fusion choices matter per layer shape and platform.  This
module turns the fixed tuple into an **extensible registry**: every
implementation of the packed xnor GEMM declares

* a unique ``name`` (what ``ProfileTable`` rows, mappings and JSON
  carry — the registry is the single resolver from name to code);
* a ``placement`` (``"host"`` or ``"device"`` — what the mapper's
  boundary-cost model keys on);
* a ``builder`` ``(a, w, k_true) -> out`` over packed operands
  ``a (B,P,Kw) int32``, ``w (N,Kw) int32``;
* an ``applicable(shape, platform)`` predicate gating which layer
  shapes / platforms the variant may be timed on;
* analytic metadata (``aspects``, ``p_blk``/``n_blk``, ``analytic``
  kind) so ``core.cost_model`` can price it on hardware we cannot run.

``DEFAULT_REGISTRY`` ships the paper's 8 configs (always applicable —
the fixed-8 space stays a subset of every autotune sweep), a fused
device-side reference (``xla_fused``: the plain XLA program with no
aspect structure, often the fastest device option on a host backend),
and the Pallas ``xnor_popcount`` kernel at several tile sizes
(``pallas_p{P}n{N}``; the 32-bit packing width is fixed by the operand
layout, tile sizes are the free parameters).  Register more with
:func:`register` / :meth:`VariantRegistry.register`.

``core.profiler.autotune_bnn_model`` sweeps the registry per layer;
``core.mapped_model`` resolves chosen names back to builders, so a
mapping is executable iff every config name is registered (or one of
the legacy fixed-8 names).

Custom ``VariantRegistry`` instances (the ``registry=`` kwarg on the
profiler/executor entry points) scope *candidate sweeps and builder
resolution*; the placement/validation authority consulted by the
mapper, serving and ``EfficientConfiguration`` round-trips is the
process-wide :data:`DEFAULT_REGISTRY` — register a variant globally
(:func:`register`) before mapping or serving it.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax

from repro.kernels.ref import xnor_gemm_ref
from repro.kernels.segment_fused import (
    build_pallas_segment,
    build_xla_segment,
    infer_in_encoding,
    segment_gemm_work,
    segment_vmem_bytes,
)
from repro.kernels.variants import xnor_gemm_variant
from repro.kernels.xnor_popcount import xnor_gemm_pallas

HOST = "host"
DEVICE = "device"
ASPECT_NAMES = ("X", "Y", "Z", "XY", "XZ", "YZ", "XYZ")

# variant scopes: a "layer" variant implements one packed xnor-GEMM
# dispatch (builder (a, w, k_true) -> out); a "segment" variant
# implements a whole same-placement layer run as one fused executable
# (builder (specs, packed_params, in_encoding=None) -> fn(x)).  The
# two scopes are separate candidate spaces: the per-layer autotuner
# sweeps layer-scope variants, the segment fuser
# (``core.plan.select_fused_segments``) sweeps segment-scope ones.
SCOPE_LAYER = "layer"
SCOPE_SEGMENT = "segment"
SCOPES = (SCOPE_LAYER, SCOPE_SEGMENT)

# The paper's 8 names are resolvable without the registry (they predate
# it, and `core.parallel_config` short-circuits on them so placement
# and pricing work without importing jax).  Their placement/aspect
# semantics are therefore frozen: re-registering one with a different
# builder is allowed (implementation hot-swap), but changing its
# placement or aspects would silently disagree with that short-circuit.
_FIXED8_META = {
    "CPU": (HOST, ()),
    **{name: (DEVICE, tuple(name)) for name in ASPECT_NAMES},
}

# non-TPU backends run Pallas in interpret mode (a Python-level grid
# walk) — cap the problem size a pallas variant is *applicable* to
# there, so live profiling sweeps stay fast; the autotuner's warm-up
# pruning catches anything the cap lets through
PALLAS_INTERPRET_MAX_WORK = 1 << 21


@dataclasses.dataclass(frozen=True)
class GemmShape:
    """Shape of one packed xnor-GEMM dispatch — what applicability
    predicates see.  ``b`` batch, ``p`` windows per image (1 for FC),
    ``n`` output neurons, ``kw`` packed reduction words."""

    b: int
    p: int
    n: int
    kw: int

    @property
    def work(self) -> int:
        """Word-level MAC count — the size proxy predicates gate on."""
        return self.b * self.p * self.n * self.kw


@dataclasses.dataclass(frozen=True)
class SegmentShape:
    """Shape of one fused-segment dispatch — what segment-scope
    applicability predicates see.  ``b`` batch, ``n_layers`` layers in
    the span, ``work`` total word-level GEMM MACs, ``vmem_bytes``
    resident footprint (weights + peak intermediate)."""

    b: int
    n_layers: int
    work: int
    vmem_bytes: int


def segment_shape_of(specs, packed_params, batch: int) -> SegmentShape:
    """The :class:`SegmentShape` of a layer slice at `batch`."""
    return SegmentShape(
        b=batch,
        n_layers=len(tuple(specs)),
        work=segment_gemm_work(specs, packed_params, batch),
        vmem_bytes=segment_vmem_bytes(
            specs, packed_params, infer_in_encoding(specs)
        ),
    )


def current_platform() -> str:
    """The JAX backend the live profiler times on (``cpu``/``tpu``/…)."""
    return jax.default_backend()


@dataclasses.dataclass(frozen=True)
class KernelVariant:
    """One registered implementation of the packed xnor GEMM."""

    name: str
    # layer scope: (a, w, k_true) -> (B, P, N) int32
    # segment scope: (specs, packed_params, in_encoding=None) -> fn(x)
    builder: Callable
    placement: str = DEVICE      # HOST or DEVICE (mapper boundary model)
    scope: str = SCOPE_LAYER     # SCOPE_LAYER or SCOPE_SEGMENT
    # analytic-pricing metadata (core.cost_model): grid order comes from
    # `aspects`, block sizes from p_blk/n_blk (None -> model defaults),
    # `analytic` picks the traffic model: "tiled" (loop-nest reuse),
    # "fused" (single pass over operands), "host" (CPU-side)
    aspects: tuple = ("X", "Y", "Z")
    p_blk: int | None = None
    n_blk: int | None = None
    analytic: str = "tiled"
    applicable: Callable | None = None   # (GemmShape, platform) -> bool
    description: str = ""

    def applies_to(self, shape: GemmShape, platform: str | None = None) -> bool:
        if self.applicable is None:
            return True
        return bool(
            self.applicable(
                shape, platform if platform is not None else current_platform()
            )
        )


class VariantRegistry:
    """Name -> KernelVariant store with applicability filtering."""

    def __init__(self):
        self._variants: dict = {}

    def register(
        self, variant: KernelVariant, *, replace: bool = False
    ) -> KernelVariant:
        if not variant.name:
            raise ValueError("variant needs a non-empty name")
        if variant.placement not in (HOST, DEVICE):
            raise ValueError(
                f"variant {variant.name!r}: placement must be "
                f"{HOST!r} or {DEVICE!r}, got {variant.placement!r}"
            )
        if variant.scope not in SCOPES:
            raise ValueError(
                f"variant {variant.name!r}: scope must be one of "
                f"{SCOPES}, got {variant.scope!r}"
            )
        if variant.name in self._variants and not replace:
            raise ValueError(
                f"variant {variant.name!r} already registered "
                "(pass replace=True to override)"
            )
        frozen = _FIXED8_META.get(variant.name)
        if frozen is not None and (
            variant.placement, tuple(variant.aspects)
        ) != frozen:
            raise ValueError(
                f"variant {variant.name!r} is a fixed-8 name with "
                f"frozen placement/aspects {frozen}; register the new "
                "semantics under a different name"
            )
        self._variants[variant.name] = variant
        return variant

    def get(self, name: str) -> KernelVariant:
        try:
            return self._variants[name]
        except KeyError:
            raise ValueError(
                f"unknown kernel variant {name!r}; registered: "
                f"{sorted(self._variants)}"
            ) from None

    def remove(self, name: str) -> KernelVariant:
        """Unregister and return `name` (ValueError if absent)."""
        return self._variants.pop(self.get(name).name)

    def __contains__(self, name: str) -> bool:
        return name in self._variants

    def __iter__(self):
        return iter(self._variants.values())

    def __len__(self) -> int:
        return len(self._variants)

    def names(self) -> tuple:
        return tuple(self._variants)

    def applicable(
        self, shape: GemmShape, platform: str | None = None
    ) -> tuple:
        """Layer-scope variants timeable for `shape` on `platform`,
        registration order (the autotuner's candidate list).  Segment
        variants are a different dispatch granularity and never appear
        here — they are swept by :meth:`applicable_segments`."""
        platform = platform if platform is not None else current_platform()
        return tuple(
            v for v in self._variants.values()
            if v.scope == SCOPE_LAYER and v.applies_to(shape, platform)
        )

    def applicable_segments(
        self, shape: SegmentShape, platform: str | None = None
    ) -> tuple:
        """Segment-scope variants timeable for a fused span of `shape`
        on `platform` (``core.profiler.profile_segment_variants``'s
        candidate list)."""
        platform = platform if platform is not None else current_platform()
        return tuple(
            v for v in self._variants.values()
            if v.scope == SCOPE_SEGMENT and v.applies_to(shape, platform)
        )

    def segment_names(self) -> tuple:
        """Names of the registered segment-scope variants."""
        return tuple(
            v.name for v in self._variants.values()
            if v.scope == SCOPE_SEGMENT
        )

    def placement_of(self, name: str) -> str:
        return self.get(name).placement


def _pallas_builder(p_blk: int, n_blk: int) -> Callable:
    def build(a, w, k_true):
        return xnor_gemm_pallas(
            a, w, k_true, ("X", "Y", "Z"),
            p_blk=p_blk, n_blk=n_blk,
            interpret=current_platform() != "tpu",
        )

    return build


def _pallas_applicable(shape: GemmShape, platform: str) -> bool:
    # native on TPU; interpret mode elsewhere only for small problems
    return platform == "tpu" or shape.work <= PALLAS_INTERPRET_MAX_WORK


# the fused kernel keeps every weight + the widest intermediate
# resident; leave headroom under the ~128 MiB v5e VMEM for Mosaic's
# own buffers
SEGMENT_VMEM_BUDGET = 96 * 1024 * 1024


def _seg_pallas_builder(specs, packed_params, in_encoding=None):
    return build_pallas_segment(
        specs, packed_params, in_encoding,
        interpret=current_platform() != "tpu",
    )


def _seg_pallas_applicable(shape: SegmentShape, platform: str) -> bool:
    if shape.vmem_bytes > SEGMENT_VMEM_BUDGET:
        return False
    return platform == "tpu" or shape.work <= PALLAS_INTERPRET_MAX_WORK


def _seg_xla_applicable(shape: SegmentShape, platform: str) -> bool:
    return True


def _register_defaults(reg: VariantRegistry) -> VariantRegistry:
    reg.register(
        KernelVariant(
            name="CPU",
            builder=xnor_gemm_ref,
            placement=HOST,
            aspects=(),
            analytic="host",
            description="paper's sequential CPU implementation "
            "(host-placed reference, no boundary cost)",
        )
    )
    for name in ASPECT_NAMES:
        reg.register(
            KernelVariant(
                name=name,
                builder=partial(
                    xnor_gemm_variant, aspects=frozenset(name)
                ),
                placement=DEVICE,
                aspects=tuple(name),
                analytic="tiled",
                description=f"aspect-structured XLA variant ({name} "
                "parallel, rest sequential)",
            )
        )
    reg.register(
        KernelVariant(
            name="xla_fused",
            builder=xnor_gemm_ref,
            placement=DEVICE,
            aspects=("X", "Y", "Z"),
            analytic="fused",
            description="device-placed fused XLA reference — no aspect "
            "structure, single pass over the operands",
        )
    )
    for p_blk, n_blk in ((64, 64), (128, 128), (128, 256)):
        reg.register(
            KernelVariant(
                name=f"pallas_p{p_blk}n{n_blk}",
                builder=_pallas_builder(p_blk, n_blk),
                placement=DEVICE,
                aspects=("X", "Y", "Z"),
                p_blk=p_blk,
                n_blk=n_blk,
                analytic="tiled",
                applicable=_pallas_applicable,
                description=f"Pallas xnor_popcount kernel, "
                f"{p_blk}x{n_blk} window/neuron tiles",
            )
        )
    reg.register(
        KernelVariant(
            name="seg_xla",
            builder=build_xla_segment,
            placement=DEVICE,
            scope=SCOPE_SEGMENT,
            aspects=("X", "Y", "Z"),
            # segment-scope analytic dispatch: "fused" prices the
            # single-pass mega-kernel, anything else the XLA-composed
            # chain (core.cost_model.xla_segment_kernel_time_tpu)
            analytic="tiled",
            applicable=_seg_xla_applicable,
            description="whole segment as one XLA executable — the "
            "layer chain jitted together, threshold/repack fused into "
            "the GEMM tails",
        )
    )
    reg.register(
        KernelVariant(
            name="seg_pallas",
            builder=_seg_pallas_builder,
            placement=DEVICE,
            scope=SCOPE_SEGMENT,
            aspects=("X",),
            analytic="fused",
            applicable=_seg_pallas_applicable,
            description="whole segment as one pallas_call: weights "
            "VMEM-resident, activations bit-packed end to end, "
            "interior results never touch HBM",
        )
    )
    return reg


#: The process-wide default registry (the paper's 8 + open extensions).
DEFAULT_REGISTRY = _register_defaults(VariantRegistry())
REGISTRY = DEFAULT_REGISTRY


def register(variant: KernelVariant, *, replace: bool = False) -> KernelVariant:
    """Register `variant` in the default registry."""
    return DEFAULT_REGISTRY.register(variant, replace=replace)


def get_variant(name: str) -> KernelVariant:
    return DEFAULT_REGISTRY.get(name)
