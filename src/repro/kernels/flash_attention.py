"""Blockwise-softmax (flash) attention Pallas kernel — the LM prefill
hot-spot (32k-token prefill would otherwise materialize a 32k x 32k
score tensor per head).

Grid (B, H, nQ, nK) with nK innermost/sequential; running max, sum and
accumulator live in VMEM scratch persisted across the nK steps
(initialized at ik == 0, written to the output block at ik == nK - 1).
GQA folding: kv-head block index = h // (H // Hkv). Causal masking uses
suffix alignment (query i sees keys j <= i + Sk - Sq) and a finite
-1e30 mask so fully-computed blocks underflow to zero weight instead of
producing NaNs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pallas_compat import compiler_params_kwargs, vmem_scratch

_NEG = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale: float, causal: bool, q_blk: int, k_blk: int, sq: int, sk: int,
):
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (q_blk, D)
    k = k_ref[0, 0].astype(jnp.float32)            # (k_blk, D)
    v = v_ref[0, 0].astype(jnp.float32)            # (k_blk, D)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                       # (q_blk, k_blk)

    if causal:
        iq = pl.program_id(2)
        qi = iq * q_blk + jax.lax.broadcasted_iota(
            jnp.int32, (q_blk, k_blk), 0
        )
        kj = ik * k_blk + jax.lax.broadcasted_iota(
            jnp.int32, (q_blk, k_blk), 1
        )
        s = jnp.where(kj <= qi + (sk - sq), s, _NEG)

    m_prev = m_ref[...]                             # (q_blk, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                          # (q_blk, k_blk)
    alpha = jnp.exp(m_prev - m_new)                 # (q_blk, 1)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    q_blk: int = 128,
    k_blk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """q (B,H,Sq,D); k,v (B,Hkv,Sk,D), Hkv | H. Returns (B,H,Sq,D)."""
    B, H, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert H % Hkv == 0
    group = H // Hkv
    q_blk = min(q_blk, Sq)
    k_blk = min(k_blk, Sk)
    if Sq % q_blk or Sk % k_blk:
        raise ValueError("Sq/Sk must be multiples of the block sizes")
    scale = float(scale if scale is not None else 1.0 / (D ** 0.5))
    nq, nk = Sq // q_blk, Sk // k_blk

    scratch = [
        vmem_scratch((q_blk, 1), jnp.float32),
        vmem_scratch((q_blk, 1), jnp.float32),
        vmem_scratch((q_blk, D), jnp.float32),
    ]
    extra = compiler_params_kwargs(
        ("parallel", "parallel", "parallel", "arbitrary")
    )

    return pl.pallas_call(
        functools.partial(
            _flash_kernel,
            scale=scale, causal=causal,
            q_blk=q_blk, k_blk=k_blk, sq=Sq, sk=Sk,
        ),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, q_blk, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec(
                (1, 1, k_blk, D),
                lambda b, h, iq, ik: (b, h // group, ik, 0),
            ),
            pl.BlockSpec(
                (1, 1, k_blk, D),
                lambda b, h, iq, ik: (b, h // group, ik, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, q_blk, D), lambda b, h, iq, ik: (b, h, iq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **extra,
    )(q, k, v)
