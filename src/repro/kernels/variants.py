"""Pure-XLA aspect-structured implementations of the xnor GEMM.

These are the 7 'GPU parallel configuration' implementations the live
profiler actually *times* on the host platform: an aspect axis is
vectorized (vmap — data-parallel), a non-aspect axis runs sequentially
(lax.map — CUDA's in-block serialization). They compile to genuinely
different XLA programs with genuinely different measured latencies,
giving the HEP mapper a real heterogeneous cost surface on any
platform, while computing the exact same function as ref.py / the
Pallas kernel (asserted in tests).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ref import xnor_gemm_ref


def _dot_word(a_k: jax.Array, w_k: jax.Array, k_true: int) -> jax.Array:
    """(Kw,) x (Kw,) -> scalar exact binary dot."""
    agree = jnp.sum(
        jax.lax.population_count(~(a_k ^ w_k)), dtype=jnp.int32
    )
    return 2 * agree - k_true


def xnor_gemm_variant(
    a: jax.Array, w: jax.Array, k_true: int, aspects
) -> jax.Array:
    """a (B,P,Kw), w (N,Kw) -> (B,P,N); aspects subset of {X,Y,Z}."""
    aspects = frozenset(aspects)

    # innermost: one window against all neurons
    if "Z" in aspects:
        def per_window(a_k):  # (Kw,) -> (N,)
            agree = jnp.sum(
                jax.lax.population_count(~(a_k[None, :] ^ w)),
                axis=-1, dtype=jnp.int32,
            )
            return 2 * agree - k_true
    else:
        def per_window(a_k):  # sequential over neurons
            return jax.lax.map(lambda w_k: _dot_word(a_k, w_k, k_true), w)

    # middle: one image (all windows)
    if "Y" in aspects:
        per_image = jax.vmap(per_window)
    else:
        def per_image(a_pk):
            return jax.lax.map(per_window, a_pk)

    # outer: batch
    if "X" in aspects:
        return jax.vmap(per_image)(a)
    return jax.lax.map(per_image, a)


def cpu_sequential(a: jax.Array, w: jax.Array, k_true: int) -> jax.Array:
    """The paper's 'CPU' implementation: the plain fused XLA reference
    (host-placed by the profiler)."""
    return xnor_gemm_ref(a, w, k_true)


ALL_VARIANTS: dict[str, object] = {
    "CPU": cpu_sequential,
    **{
        cfg: partial_cfg
        for cfg, partial_cfg in (
            (name, partial(xnor_gemm_variant, aspects=frozenset(name)))
            for name in ("X", "Y", "Z", "XY", "XZ", "YZ", "XYZ")
        )
    },
}
