"""Jit'd public entry points for the kernels.

``backend`` selects the execution tier:
  * ``'ref'``     — pure-jnp oracle (the CPU implementation)
  * ``'variant'`` — aspect-structured XLA program (profiled live)
  * ``'pallas'``  — the Pallas TPU kernel (``interpret=True`` on CPU)
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels import ref as _ref
from repro.kernels.variants import xnor_gemm_variant
from repro.kernels.xnor_popcount import xnor_gemm_pallas
from repro.kernels.flash_attention import flash_attention_pallas


@partial(
    jax.jit,
    static_argnames=("k_true", "aspects", "backend", "interpret",
                     "p_blk", "n_blk"),
)
def xnor_gemm(
    a: jax.Array,
    w: jax.Array,
    *,
    k_true: int,
    aspects: tuple = ("X", "Y", "Z"),
    backend: str = "ref",
    interpret: bool = True,
    p_blk: int = 128,
    n_blk: int = 128,
) -> jax.Array:
    if backend == "ref":
        return _ref.xnor_gemm_ref(a, w, k_true)
    if backend == "variant":
        return xnor_gemm_variant(a, w, k_true, frozenset(aspects))
    if backend == "pallas":
        return xnor_gemm_pallas(
            a, w, k_true, aspects,
            p_blk=p_blk, n_blk=n_blk, interpret=interpret,
        )
    raise ValueError(f"unknown backend {backend!r}")


@partial(
    jax.jit,
    static_argnames=("k_true", "aspects", "backend", "interpret",
                     "p_blk", "n_blk"),
)
def binary_conv2d(
    x_words: jax.Array,
    w_words: jax.Array,
    *,
    k_true: int,
    aspects: tuple = ("X", "Y", "Z"),
    backend: str = "ref",
    interpret: bool = True,
    p_blk: int = 128,
    n_blk: int = 128,
) -> jax.Array:
    """Packed 3x3 SAME conv = window extraction + xnor GEMM.
    x_words (B,H,W,Cw), w_words (Cout, 9*Cw) -> (B,H,W,Cout) int32."""
    from repro.bnn.layers import extract_patch_words

    b, h, w_, _ = x_words.shape
    patches = extract_patch_words(x_words).reshape(b, h * w_, -1)
    out = xnor_gemm(
        patches, w_words,
        k_true=k_true, aspects=aspects, backend=backend,
        interpret=interpret, p_blk=p_blk, n_blk=n_blk,
    )
    return out.reshape(b, h, w_, -1)


@partial(
    jax.jit,
    static_argnames=("causal", "scale", "backend", "interpret",
                     "q_blk", "k_blk"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    backend: str = "pallas",
    interpret: bool = True,
    q_blk: int = 128,
    k_blk: int = 128,
) -> jax.Array:
    if backend == "ref":
        return _ref.attention_ref(q, k, v, causal=causal, scale=scale).astype(
            q.dtype
        )
    return flash_attention_pallas(
        q, k, v, causal=causal, scale=scale,
        q_blk=q_blk, k_blk=k_blk, interpret=interpret,
    )
