"""Fused whole-segment kernels: one dispatch per device segment, with
activations staying as int32 bitplane words end to end.

The per-layer executors launch one kernel per layer and let every
conv/fc write its unpacked int32 pre-activations back to HBM, only for
the following step layer to read them again, threshold, and repack.
FINN / Larq-CE-style engines get their headline BNN wins by *fusing*
that chain: GEMM -> threshold -> repack happens in on-chip memory and
the segment's interior activations never materialize off-chip.

Two segment-scope builders, registered as ``KernelVariant``\\ s
(``scope="segment"``) so the profiler, DP mapper and serving runtime
price and select them like any other variant:

* ``seg_xla`` — the segment's reference layer chain under a single
  ``jax.jit``: XLA fuses the elementwise tail of each GEMM
  (threshold + shift/or repack) into one executable and launches the
  segment as one dispatch.  Applicable everywhere; the measured
  fallback on hosts without a TPU.
* ``seg_pallas`` — the whole segment as **one** ``pallas_call``: grid
  over the batch (X-parallel, one example per program), every weight /
  threshold array resident in VMEM, and the full layer chain —
  patch-word gather, xnor/popcount GEMM, reshape-max pool, integer
  threshold + bitplane repack, flatten, FC — unrolled inside the
  kernel body.  Interior activations live only in VMEM/registers;
  HBM sees packed words at the segment edges (plus the final int32
  scores).  Runs natively on TPU and in interpret mode elsewhere.

Both builders compute the exact reference semantics (they reuse the
``repro.bnn.layers`` packed ops on a per-example block), so fused
execution is bit-exact against per-layer execution by construction.

Builder signature (segment scope): ``builder(specs, packed_params,
in_encoding=None) -> fn(x) -> out`` over the segment's layer slice.
``in_encoding`` ("packed" / "unpacked") disambiguates a segment that
*starts* with maxpool layers (mp preserves either encoding); for any
other first layer it is implied by the layer kind.
"""

from __future__ import annotations

import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.bnn import layers as L
from repro.bnn.binarize import PACK_W
from repro.kernels.pallas_compat import compiler_params_kwargs

PACKED = "packed"
UNPACKED = "unpacked"

# layer kinds whose input encoding is implied by the kind itself
_IN_ENCODING = {
    "conv": PACKED, "fc": PACKED, "flat": PACKED, "step": UNPACKED,
}


def infer_in_encoding(specs: Sequence[L.LayerSpec]) -> str:
    """The encoding a segment's input must arrive in, from its first
    non-mp layer (mp preserves either).  An all-mp segment defaults to
    unpacked — pooling packed words would OR bitplanes, which no valid
    chain produces mid-network without an adjacent non-mp layer."""
    for spec in specs:
        if spec.kind in _IN_ENCODING:
            return _IN_ENCODING[spec.kind]
    return UNPACKED


def encoded_shape(shape: tuple, encoding: str) -> tuple:
    """Per-example array shape for a logical (unpacked) layer shape
    under `encoding`: packed divides the channel axis into 32-bit
    words."""
    if encoding == UNPACKED:
        return tuple(shape)
    return tuple(shape[:-1]) + (math.ceil(shape[-1] / PACK_W),)


def segment_out_encoding(
    specs: Sequence[L.LayerSpec], in_encoding: str
) -> str:
    enc = in_encoding
    for spec in specs:
        if spec.kind in ("conv", "fc"):
            enc = UNPACKED
        elif spec.kind == "step":
            enc = PACKED
        elif spec.kind == "flat":
            enc = PACKED
    return enc


def _run_chain(specs: Sequence[L.LayerSpec], packed_params, x):
    """The segment's reference layer chain on a batched array —
    the single source of semantics for both fused builders."""
    for spec, p in zip(specs, packed_params):
        if spec.kind == "conv":
            x = L.conv_packed(x, p["w_words"], p["k_true"])
        elif spec.kind == "mp":
            x = L.maxpool_packed(x)
        elif spec.kind == "step":
            x = L.step_packed(x, p["thresh"], p["flip"])
        elif spec.kind == "flat":
            x = L.flat_packed(x, spec.in_shape[-1])
        elif spec.kind == "fc":
            x = L.fc_packed(x, p["w_words"], p["k_true"])
        else:
            raise ValueError(spec.kind)
    return x


def segment_weight_bytes(packed_params) -> int:
    """Bytes of parameter data the fused kernel keeps resident."""
    total = 0
    for p in packed_params:
        for v in p.values():
            if hasattr(v, "size"):
                total += int(v.size) * 4
    return total


def segment_vmem_bytes(
    specs: Sequence[L.LayerSpec],
    packed_params,
    in_encoding: str | None = None,
) -> int:
    """Resident-footprint estimate of the fused kernel per example:
    all weights plus the largest unpacked intermediate (double-buffered
    in/out).  Applicability gates on this against the VMEM budget."""
    if in_encoding is None:
        in_encoding = infer_in_encoding(specs)
    peak = 0
    enc = in_encoding
    for spec in specs:
        in_elems = 1
        for d in encoded_shape(spec.in_shape, enc):
            in_elems *= d
        if spec.kind in ("conv", "fc"):
            enc = UNPACKED
        elif spec.kind == "step":
            enc = PACKED
        out_elems = 1
        for d in encoded_shape(spec.out_shape, enc):
            out_elems *= d
        peak = max(peak, (in_elems + out_elems) * 4)
    return segment_weight_bytes(packed_params) + peak


def segment_gemm_work(
    specs: Sequence[L.LayerSpec], packed_params, batch: int
) -> int:
    """Total word-level MAC count of the segment's GEMM layers at
    `batch` — the interpret-mode size proxy (``GemmShape.work``
    summed)."""
    work = 0
    for spec, p in zip(specs, packed_params):
        if spec.kind not in ("conv", "fc"):
            continue
        n, kw = (int(d) for d in p["w_words"].shape)
        pwin = spec.in_shape[0] * spec.in_shape[1] if spec.kind == "conv" else 1
        work += batch * pwin * n * kw
    return work


# ---------------------------------------------------------------------------
# seg_xla: the segment chain as one XLA executable
# ---------------------------------------------------------------------------


def build_xla_segment(
    specs: Sequence[L.LayerSpec],
    packed_params,
    in_encoding: str | None = None,
):
    """One jitted executable for the whole segment — XLA fuses the
    GEMM tails (threshold/repack) so the chain is a single dispatch."""
    specs = tuple(specs)
    packed_params = tuple(packed_params)

    @jax.jit
    def run(x):
        return _run_chain(specs, packed_params, x)

    return run


# ---------------------------------------------------------------------------
# seg_pallas: the whole segment as one pallas_call
# ---------------------------------------------------------------------------


def _segment_kernel(x_ref, *refs, specs, param_slots, k_trues):
    """Pallas kernel body: one example's full segment chain.  The
    block shapes keep a leading batch dim of 1, so the reference layer
    ops apply unchanged — interior activations are kernel-local values
    (VMEM/registers), never written to HBM."""
    x = x_ref[...]                       # (1, *in_shape)
    params = []
    for spec, slot in zip(specs, param_slots):
        if spec.kind in ("conv", "fc"):
            params.append(
                {"w_words": refs[slot][...], "k_true": k_trues[spec.idx]}
            )
        elif spec.kind == "step":
            params.append(
                {
                    "thresh": refs[slot][...],
                    # flip travels as int32 (TPU-friendly); the xor in
                    # step_packed needs the original bool semantics
                    "flip": refs[slot + 1][...].astype(jnp.bool_),
                }
            )
        else:
            params.append({})
    out = _run_chain(specs, params, x)
    refs[-1][...] = out.astype(jnp.int32)


def build_pallas_segment(
    specs: Sequence[L.LayerSpec],
    packed_params,
    in_encoding: str | None = None,
    *,
    interpret: bool | None = None,
):
    """The whole segment as one ``pallas_call``.

    Grid is ``(B,)`` with X parallel — one example per program, the
    paper's X aspect at segment granularity.  Every parameter array is
    a full-block VMEM input (weights stay resident across the chain);
    the input/output blocks carry one example in the segment's edge
    encodings.  Returns ``fn(x) -> out`` with reference semantics.
    """
    specs = tuple(specs)
    if in_encoding is None:
        in_encoding = infer_in_encoding(specs)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    out_encoding = segment_out_encoding(specs, in_encoding)
    in_shape = encoded_shape(specs[0].in_shape, in_encoding)
    out_shape = encoded_shape(specs[-1].out_shape, out_encoding)

    # flatten parameter arrays into pallas inputs; record, per layer,
    # its first slot index in that flat list
    arrays, param_slots, k_trues = [], [], {}
    for spec, p in zip(specs, packed_params):
        param_slots.append(len(arrays))
        if spec.kind in ("conv", "fc"):
            arrays.append(jnp.asarray(p["w_words"], jnp.int32))
            k_trues[spec.idx] = int(p["k_true"])
        elif spec.kind == "step":
            arrays.append(jnp.asarray(p["thresh"], jnp.int32))
            arrays.append(jnp.asarray(p["flip"], jnp.int32))

    kernel = functools.partial(
        _segment_kernel,
        specs=specs,
        param_slots=tuple(param_slots),
        k_trues=k_trues,
    )
    param_specs = [
        pl.BlockSpec(a.shape, lambda *idx, _nd=a.ndim: (0,) * _nd)
        for a in arrays
    ]

    def run(x):
        b = x.shape[0]
        call = pl.pallas_call(
            kernel,
            grid=(b,),
            in_specs=[
                pl.BlockSpec(
                    (1,) + in_shape,
                    lambda i: (i,) + (0,) * len(in_shape),
                ),
                *param_specs,
            ],
            out_specs=pl.BlockSpec(
                (1,) + out_shape,
                lambda i: (i,) + (0,) * len(out_shape),
            ),
            out_shape=jax.ShapeDtypeStruct((b,) + out_shape, jnp.int32),
            interpret=interpret,
            **compiler_params_kwargs(("parallel",)),
        )
        return call(x, *arrays)

    return jax.jit(run)
