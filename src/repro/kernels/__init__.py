"""Pallas TPU kernels for the paper's compute hot-spots.

* ``xnor_popcount`` — the BNN binary GEMM (conv-as-GEMM and FC), grid
  parameterized by the paper's X/Y/Z parallelism aspects (see
  docs/ARCHITECTURE.md §2): aspect axes become *parallel* grid
  dimensions, non-aspect axes
  *arbitrary* (sequential) ones — the TPU-native analogue of CUDA
  thread-block decomposition vs in-block serialization.
* ``flash_attention`` — blockwise-softmax attention for LM prefill.

Each kernel ships with ``ref.py`` (pure-jnp oracle) and ``ops.py``
(jit'd entry points). ``variants.py`` holds the pure-XLA aspect-
structured implementations that the live profiler times on the host
platform (kernels are validated in interpret mode; their TPU cost comes
from the analytic model in ``repro.core.cost_model``).

``registry.py`` is the open kernel-variant registry: every GEMM
implementation — the fixed 8, the fused device reference, Pallas tile
variants, and anything registered later — declares its name,
placement, applicability predicate, and builder there; the profiler's
autotune sweep and the mapped-model executors resolve variants through
it (see docs/ARCHITECTURE.md §8).
"""

from repro.kernels.ops import (
    xnor_gemm,
    binary_conv2d,
    flash_attention,
)
from repro.kernels.registry import (
    DEFAULT_REGISTRY,
    GemmShape,
    KernelVariant,
    VariantRegistry,
    get_variant,
    register,
)
