"""Pure-jnp oracles for every kernel (the 'CPU' implementations in the
paper's sense, and the ground truth for allclose tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def xnor_gemm_ref(a: jax.Array, w: jax.Array, k_true: int) -> jax.Array:
    """a (B,P,Kw) int32, w (N,Kw) int32 -> (B,P,N) int32."""
    xn = ~(a[:, :, None, :] ^ w[None, None, :, :])
    agree = jnp.sum(jax.lax.population_count(xn), axis=-1, dtype=jnp.int32)
    return 2 * agree - k_true


def binary_conv2d_ref(
    x_words: jax.Array, w_words: jax.Array, k_true: int
) -> jax.Array:
    """Packed 3x3 SAME binary conv oracle (delegates to bnn.layers)."""
    from repro.bnn.layers import conv_packed

    return conv_packed(x_words, w_words, k_true)


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    """Naive softmax attention oracle.

    q (B,H,Sq,D); k,v (B,Hkv,Sk,D) with H a multiple of Hkv (GQA);
    returns (B,H,Sq,D) float32.
    """
    B, H, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    scale = scale if scale is not None else 1.0 / jnp.sqrt(D).astype(jnp.float32)
    group = H // Hkv
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)
    ) * scale
    if causal:
        # causal over the *suffix alignment*: query i attends to keys
        # j <= i + (Sk - Sq) (standard decode/prefill convention)
        qi = jnp.arange(Sq)[:, None]
        kj = jnp.arange(Sk)[None, :]
        mask = kj <= qi + (Sk - Sq)
        logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
