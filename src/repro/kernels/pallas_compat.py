"""Version-compat shims for the Pallas TPU extension module.

The TPU compiler-params class was renamed across jax releases
(``pltpu.TPUCompilerParams`` on jax 0.4.x, ``pltpu.CompilerParams``
on newer releases).  Kernels that construct it directly crash on one
side of the rename; worse, a bare ``except`` around the construction
silently drops ``dimension_semantics`` so every aspect configuration
compiles identically.  Both kernels (``xnor_popcount``,
``flash_attention``) resolve the class through here instead.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax.numpy as jnp

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover - pallas builds without TPU ext
    pltpu = None

# The compiler-params class under either of its names, or None when the
# TPU extension is unavailable entirely.
_COMPILER_PARAMS_CLS = (
    getattr(pltpu, "CompilerParams", None)
    or getattr(pltpu, "TPUCompilerParams", None)
    if pltpu is not None
    else None
)


def tpu_compiler_params(
    dimension_semantics: Sequence[str], **kwargs: Any
):
    """Build TPU compiler params carrying ``dimension_semantics``.

    Returns None when no compatible class exists (pure-interpreter
    environments) — callers must then omit ``compiler_params`` from
    ``pallas_call`` rather than pass a wrong-typed value.
    """
    if _COMPILER_PARAMS_CLS is None:
        return None
    return _COMPILER_PARAMS_CLS(
        dimension_semantics=tuple(dimension_semantics), **kwargs
    )


def compiler_params_kwargs(
    dimension_semantics: Sequence[str], **kwargs: Any
) -> dict:
    """``**``-splattable ``{"compiler_params": ...}`` (or ``{}``)."""
    params = tpu_compiler_params(dimension_semantics, **kwargs)
    return {"compiler_params": params} if params is not None else {}


def vmem_scratch(shape: tuple, dtype) -> Any:
    """A VMEM scratch allocation, degrading to a backend-neutral
    ``MemoryRef`` in ``MemorySpace.ANY`` for interpreter-mode
    environments where the TPU extension (and its memory-space
    constructors) is absent."""
    if pltpu is not None and hasattr(pltpu, "VMEM"):
        return pltpu.VMEM(shape, dtype)
    try:
        from jax._src.pallas import core as pallas_core

        return pallas_core.MemoryRef(
            tuple(shape), jnp.dtype(dtype), pallas_core.MemorySpace.ANY
        )
    except Exception as e:  # pragma: no cover
        raise ImportError(
            "no usable Pallas scratch allocator: the TPU extension is "
            "unavailable and jax._src.pallas.core.MemoryRef could not "
            "be constructed on this jax version"
        ) from e
