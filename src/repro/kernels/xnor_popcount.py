"""Bit-packed xnor/popcount binary GEMM as a Pallas TPU kernel.

Unified layer compute (see docs/ARCHITECTURE.md §2): activations are packed words
``a (B, P, Kw) int32`` (P = conv windows per image, or 1 for FC), weights
``w (N, Kw) int32`` (N output channels / neurons), output
``o (B, P, N) int32`` with the exact {-1,+1} dot product
``2 * popcount(xnor) - k_true``.

X/Y/Z aspect mapping (paper §II-C -> TPU):
  X (data)   -> grid over B, one image per parallel step
  Y (window) -> grid over P tiles of ``p_blk`` windows
  Z (neuron) -> grid over N tiles of ``n_blk`` channels

All three axes are always grid dimensions (so VMEM blocks stay bounded);
an aspect makes its dimension **parallel** (outermost, Mosaic
``dimension_semantics='parallel'`` — distributed over TensorCores), a
non-aspect dimension is **arbitrary** (innermost, sequential — CUDA's
"images processed one after another in a thread block"). This preserves
the paper's 8-way configuration space with TPU-native semantics: the
aspect choice changes grid order and therefore weight/activation block
reuse distance, i.e. HBM traffic (modeled in core/cost_model.py).

This is a VPU (vector-unit) workload — popcount/xor are not MXU ops; the
MXU idles. BlockSpec lane dims are kept at multiples of 8x128 where the
problem allows; int32 words mean Kw is typically small (<=160 words).
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pallas_compat import compiler_params_kwargs

ASPECTS_ALL = ("X", "Y", "Z")


def _norm_aspects(aspects) -> tuple:
    s = frozenset(aspects)
    bad = s - set(ASPECTS_ALL)
    if bad:
        raise ValueError(f"unknown aspects {bad}")
    return tuple(a for a in ASPECTS_ALL if a in s)  # canonical X,Y,Z order


def _kernel(a_ref, w_ref, o_ref, *, k_true: int):
    # a_ref: (1, p_blk, Kw); w_ref: (n_blk, Kw); o_ref: (1, p_blk, n_blk)
    a = a_ref[0]                      # (p_blk, Kw)
    w = w_ref[...]                    # (n_blk, Kw)
    xn = ~(a[:, None, :] ^ w[None, :, :])         # (p_blk, n_blk, Kw)
    # population_count on int32 counts two's-complement bits — exactly
    # the packed-lane agreement count
    agree = jnp.sum(jax.lax.population_count(xn), axis=-1, dtype=jnp.int32)
    o_ref[0] = (2 * agree - k_true).astype(jnp.int32)


def xnor_gemm_pallas(
    a: jax.Array,
    w: jax.Array,
    k_true: int,
    aspects: Sequence[str] = ("X", "Y", "Z"),
    *,
    p_blk: int = 128,
    n_blk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Pallas xnor GEMM. a (B,P,Kw) int32, w (N,Kw) int32 -> (B,P,N)."""
    B, P, Kw = a.shape
    N, Kw2 = w.shape
    assert Kw == Kw2, (Kw, Kw2)
    aspects = _norm_aspects(aspects)
    p_blk = min(p_blk, P)
    n_blk = min(n_blk, N)

    # grid axes in canonical (B, P, N) order, then re-ordered so aspect
    # (parallel) dims are outermost
    axis_order = [ax for ax in ("X", "Y", "Z") if ax in aspects] + [
        ax for ax in ("X", "Y", "Z") if ax not in aspects
    ]
    sizes = {"X": B, "Y": pl.cdiv(P, p_blk), "Z": pl.cdiv(N, n_blk)}
    grid = tuple(sizes[ax] for ax in axis_order)
    pos = {ax: i for i, ax in enumerate(axis_order)}

    def a_index(*idx):
        return (idx[pos["X"]], idx[pos["Y"]], 0)

    def w_index(*idx):
        return (idx[pos["Z"]], 0)

    def o_index(*idx):
        return (idx[pos["X"]], idx[pos["Y"]], idx[pos["Z"]])

    dim_sem = tuple(
        "parallel" if ax in aspects else "arbitrary" for ax in axis_order
    )

    return pl.pallas_call(
        functools.partial(_kernel, k_true=k_true),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, p_blk, Kw), a_index),
            pl.BlockSpec((n_blk, Kw), w_index),
        ],
        out_specs=pl.BlockSpec((1, p_blk, n_blk), o_index),
        out_shape=jax.ShapeDtypeStruct((B, P, N), jnp.int32),
        interpret=interpret,
        **compiler_params_kwargs(dim_sem),
    )(a, w)
