"""End-to-end LM training driver: any assigned architecture (reduced or
full), synthetic k-gram token stream, AdamW + cosine schedule, atomic
checkpoints with exact resume, straggler watchdog.

    PYTHONPATH=src python examples/train_lm.py --arch qwen2_0_5b \
        --steps 60 --batch 8 --seq 128
    # kill it mid-run and re-run: it resumes from the latest checkpoint
    # (--ckpt sets the checkpoint dir, default results/train_lm_ckpt)

    --full uses the exact assigned config (for real hardware; the smoke
    config is the CPU default).
"""

import argparse

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.data import make_token_stream
from repro.models.steps import make_train_step
from repro.models.transformer import init_params
from repro.optim import adamw, linear_warmup_cosine
from repro.runtime import LoopConfig, TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_0_5b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt", default="results/train_lm_ckpt")
    args = ap.parse_args()

    cfg = C.get(args.arch) if args.full else C.get_smoke(args.arch)
    print(f"arch={cfg.name}  params={cfg.n_params()/1e6:.1f}M")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(linear_warmup_cosine(3e-3, 10, args.steps))
    opt_state = opt.init(params)
    raw_step = make_train_step(cfg, opt)
    sample = make_token_stream(0, cfg.vocab)

    @jax.jit
    def step_fn(state, batch):
        params, opt_state = state
        params, opt_state, metrics = raw_step(params, opt_state, batch)
        return (params, opt_state), metrics

    def batch_fn(step):
        toks = sample(step, args.batch, args.seq)
        batch = {"tokens": toks, "labels": toks}
        if cfg.n_frontend_embeds:
            batch["frontend_embeds"] = jnp.zeros(
                (args.batch, cfg.n_frontend_embeds, cfg.d_model),
                cfg.dtype,
            )
        return batch

    loop = TrainLoop(
        step_fn, batch_fn, (params, opt_state),
        LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt,
                   save_every=20, async_save=True),
        on_straggler=lambda s, dt: print(f"  [watchdog] slow step {s}: {dt:.2f}s"),
    )
    resumed = loop.restore_if_available()
    if resumed:
        print(f"resumed from checkpoint at step {resumed}")
    out = loop.run()
    first = out["metrics"][0] if out["metrics"] else {}
    last = out["metrics"][-1] if out["metrics"] else {}
    print(
        f"steps {loop.start_step}->{out['final_step']}  "
        f"loss {first.get('loss', float('nan')):.3f} -> "
        f"{last.get('loss', float('nan')):.3f}"
    )


if __name__ == "__main__":
    main()
