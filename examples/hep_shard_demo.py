import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""HEP-Shard demo: the paper's greedy mapping algorithm selecting a
sharding scheme for an LM cell from compiled dry-run costs, on a local
8-device debug mesh (2 data x 4 model).

    PYTHONPATH=src python examples/hep_shard_demo.py --arch olmo_1b
"""

import argparse
import dataclasses

import jax

from repro import configs as C
from repro.core.cost_model import HOST_LATENCY, HOST_LINK_BW
from repro.core.hep_shard import ShardTrial, search
from repro.launch import hlo_analysis as H
from repro.launch.dryrun import HBM_BW, ICI_BW, PEAK_BF16, build_lowered


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo_1b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--layers", type=int, default=2)
    args = ap.parse_args()

    cfg = dataclasses.replace(C.get(args.arch), n_layers=args.layers)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    sh = C.SHAPES[args.shape]
    # real per-step host staging: the token batch up (ids + targets,
    # int32) and the metrics scalars down — parameters and optimizer
    # state stay device-resident across steps and are NOT charged.
    # The global batch reaches the devices whole under every scheme, so
    # this term is scheme-invariant: reporting-only, it never moves the
    # search's argmin (scheme-dependent staging would mis-price
    # resident state, the bias the layer-level DP exists to avoid)
    step_in_bytes = 2 * sh.batch * sh.seq * 4

    def evaluate(scheme):
        compiled = build_lowered(cfg, args.shape, mesh, scheme).compile()
        txt = compiled.as_text()
        mem = compiled.memory_analysis()
        peak = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                + mem.output_size_in_bytes - mem.alias_size_in_bytes)
        return ShardTrial(
            scheme=scheme,
            compute_s=H.dot_flops(txt) / PEAK_BF16,
            memory_s=H.hbm_bytes(txt) / HBM_BW,
            collective_s=H.collective_bytes(txt, 8).total_bytes / ICI_BW,
            peak_bytes=peak,
            # host staging split the same way the layer profiler splits
            # kernel vs boundary
            h2d_s=HOST_LATENCY + step_in_bytes / HOST_LINK_BW,
            d2h_s=HOST_LATENCY,
        )

    knobs = {  # reduced lattice for the demo
        "tp": (True, False),
        "fsdp": ("zero1", "zero3"),
        "batch_over_model": (False, True),
    }
    best, history = search(evaluate, knobs=knobs, max_rounds=2)
    print(f"\nevaluated {len(history)} trials; best scheme:")
    print(f"  {best.scheme}")
    print(
        f"  compute {best.compute_s*1e3:.2f}ms  "
        f"memory {best.memory_s*1e3:.2f}ms  "
        f"collective {best.collective_s*1e3:.2f}ms  "
        f"transfer {best.transfer_s*1e3:.2f}ms  "
        f"peak {best.peak_bytes/2**30:.2f}GiB"
    )


if __name__ == "__main__":
    main()
