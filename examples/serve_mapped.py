"""Batched-request serving through the HEP-mapped BNN.

A request queue is drained in batches of the mapper's *proper batch
size* (the paper's deployment story: the generated efficient
configuration is what you put behind the endpoint). Reports latency
percentiles and verifies every response against the reference model.

    PYTHONPATH=src python examples/serve_mapped.py
"""

import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.bnn import build_model
from repro.bnn.models import (
    forward_packed, pack_params, prepare_input_packed,
)
from repro.core import build_mapped_model, map_efficient_configuration
from repro.core.profiler import profile_bnn_model
from repro.data import make_image_dataset


def main():
    model = build_model("fashion_mnist", scale=0.5)
    packed = pack_params(model.specs, model.init(jax.random.PRNGKey(0)))

    table = profile_bnn_model(model, packed, batch_sizes=(1, 4, 16),
                              repeats=2)
    ec = map_efficient_configuration(table)
    artifact = Path("results") / "efficient_config_fmnist.json"
    artifact.parent.mkdir(exist_ok=True)
    artifact.write_text(ec.to_json())
    print(f"wrote mapping artifact -> {artifact}")

    mapped = build_mapped_model(model, packed, ec)
    bs = ec.proper_batch_size

    ds = make_image_dataset(7, 512, model.input_hw, model.in_channels)
    lat = []
    correct = 0
    for i in range(0, 512 - bs + 1, bs):
        x = ds.x[i : i + bs]
        xw = prepare_input_packed(x)
        t0 = time.perf_counter()
        scores = mapped(xw)
        jax.block_until_ready(scores)
        lat.append((time.perf_counter() - t0) / bs)
        ref = forward_packed(model.specs, packed, xw)
        assert np.array_equal(np.asarray(scores), np.asarray(ref))
        correct += int(np.sum(np.argmax(np.asarray(scores), -1)
                              == ds.y[i : i + bs]))
    lat_us = np.asarray(lat) * 1e6
    n = (512 // bs) * bs
    print(
        f"served {n} requests @ batch {bs}: "
        f"p50 {np.percentile(lat_us,50):.0f}us/img  "
        f"p99 {np.percentile(lat_us,99):.0f}us/img  "
        f"(untrained acc {correct/n:.3f})"
    )
    print("all responses verified exact vs reference")


if __name__ == "__main__":
    main()
