"""Batched-request serving through the HEP-mapped BNN, via the
``repro.api`` facade — the blessed profile → map → serve path.

``Deployment.plan`` profiles the model and maps it with the
transfer-aware DP; ``serve()`` stands up the segment-pipelined
engine: single-example requests are coalesced by the dynamic
micro-batcher (max-batch = the mapper's proper batch size, partial
batches padded to a profiled batch size) and executed as a two-stage
host/device segment pipeline.  Reports p50/p99 request latency and
verifies every response bit-exact against the reference model.

    PYTHONPATH=src python examples/serve_mapped.py
    PYTHONPATH=src python examples/serve_mapped.py \
        --requests 256 --scale 0.25 --policy greedy --max-wait-ms 5
"""

import argparse
from pathlib import Path

import jax
import numpy as np

from repro import api
from repro.bnn import build_model
from repro.bnn.models import (
    forward_packed, pack_params, prepare_input_packed,
)
from repro.data import make_image_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.5)
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--policy", default="dp", choices=("greedy", "dp"))
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    args = ap.parse_args()

    model = build_model("fashion_mnist", scale=args.scale)
    packed = pack_params(model.specs, model.init(jax.random.PRNGKey(0)))

    dep = api.Deployment.plan(
        (model, packed),
        batch_sizes=(1, 4, 16), policy=args.policy, repeats=2,
    )
    ec = dep.configuration()
    # own filename: results/efficient_config_fmnist.json is the
    # committed legacy-schema fixture tests round-trip — never clobber
    artifact = Path("results") / "serve_mapped_config.json"
    artifact.parent.mkdir(exist_ok=True)
    artifact.write_text(ec.to_json())
    print(f"wrote mapping artifact -> {artifact}")
    segs = ec.segments()
    print(
        f"schedule: {len(segs)} segments "
        + " ".join(f"[{s.placement[0].upper()}x{len(s)}]" for s in segs)
        + f", proper batch {ec.proper_batch_size}"
    )

    dep.serve(max_wait_s=args.max_wait_ms * 1e-3)

    n = args.requests
    ds = make_image_dataset(7, n, model.input_hw, model.in_channels)
    xw_all = np.asarray(prepare_input_packed(ds.x))
    # trickle requests in, stepping as we go: full micro-batches drain
    # immediately, stragglers age out under --max-wait-ms, and a final
    # forced drain flushes the partial tail
    reqs = []
    served = 0
    for i in range(n):
        reqs.append(dep.submit(xw_all[i]))
        served += dep.step()
    served += dep.drain()
    assert served == n

    ref = np.asarray(forward_packed(model.specs, packed, xw_all))
    correct = 0
    lat_us = []
    for i, r in enumerate(reqs):
        scores = r.wait(timeout=1.0)
        assert np.array_equal(scores, ref[i]), f"response {i} mismatch"
        lat_us.append(r.latency_s * 1e6)
        correct += int(np.argmax(scores) == ds.y[i])
    lat_us = np.asarray(lat_us)
    stats = dep.stats()
    print(
        f"served {stats['served']} requests @ max_batch "
        f"{ec.proper_batch_size}: "
        f"p50 {np.percentile(lat_us, 50):.0f}us  "
        f"p99 {np.percentile(lat_us, 99):.0f}us  "
        f"(untrained acc {correct / n:.3f})"
    )
    print("all responses verified exact vs reference")


if __name__ == "__main__":
    main()
