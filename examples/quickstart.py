"""Quickstart: train a small BNN with the STE recipe, quantize to
bit-packed inference form, let HEP-BNN map each layer to its fastest
implementation, and run the mapped model.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.bnn import build_model
from repro.bnn.models import (
    forward_packed, pack_params, prepare_input_packed,
)
from repro.bnn.train import eval_step, init_train_state, train_step
from repro.core import build_mapped_model, map_efficient_configuration
from repro.core.mapper import best_uniform
from repro.core.profiler import profile_bnn_model
from repro.data import ShardedBatcher, make_image_dataset


def main():
    # 1. train (synthetic Fashion-MNIST stand-in — offline container)
    model = build_model("fashion_mnist", scale=0.5)
    ds = make_image_dataset(0, 2048, model.input_hw, model.in_channels)
    state, opt = init_train_state(model, jax.random.PRNGKey(0), lr=2e-3)
    batcher = ShardedBatcher(n=2048, global_batch=64, seed=0)
    for step in range(60):
        x, y = batcher.batch((ds.x, ds.y), step)
        state, metrics = train_step(model, opt, state, x, y)
    xe, ye = batcher.batch((ds.x, ds.y), 9_999)
    print(f"eval acc after 60 steps: {eval_step(model, state.params, xe, ye):.3f}")

    # 2. quantize -> packed xnor/popcount inference model
    packed = pack_params(model.specs, state.params)

    # 3. HEP-BNN: profile every layer under all 8 implementations,
    #    then map with both policies — the paper's greedy Algorithm 1
    #    and the transfer-aware DP that prices the fused executor
    table = profile_bnn_model(
        model, packed, batch_sizes=(1, 4, 16), repeats=2
    )
    ec_greedy = map_efficient_configuration(table, policy="greedy")
    ec = map_efficient_configuration(table, policy="dp")
    print(f"proper batch size: {ec.proper_batch_size}")
    for l, c, k, b in zip(
        ec.layer_labels, ec.layer_configs,
        ec.per_layer_kernel_times, ec.per_layer_boundary_times,
    ):
        print(f"  {l:12s} -> {c:4s} kernel {k*1e6:7.1f}us "
              f"boundary {b*1e6:7.1f}us")
    _, t_xyz = best_uniform(table, "XYZ")
    print(
        f"HEP-dp {ec.expected_time_per_example*1e6:.0f} us/img vs "
        f"HEP-greedy {ec_greedy.expected_time_per_example*1e6:.0f} us/img vs "
        f"full-XYZ {t_xyz*1e6:.0f} us/img "
        f"(dp is {t_xyz/ec.expected_time_per_example:.2f}x vs XYZ, "
        f"{ec_greedy.expected_time_per_example/ec.expected_time_per_example:.2f}x vs greedy)"
    )

    # 4. build + run the mapped model; verify exactness
    mapped = build_mapped_model(model, packed, ec)
    x, _ = batcher.batch((ds.x, ds.y), 123)
    x = x[: ec.proper_batch_size]
    xw = prepare_input_packed(x)
    out = mapped(xw)
    ref = forward_packed(model.specs, packed, xw)
    assert np.array_equal(np.asarray(out), np.asarray(ref))
    print("mapped model output == reference (exact)")


if __name__ == "__main__":
    main()
