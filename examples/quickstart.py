"""Quickstart: train a small BNN with the STE recipe, quantize to
bit-packed inference form, let HEP-BNN map each layer to its fastest
implementation, run the mapped model, and serve it through the
segment-pipelined engine (the README's train -> profile -> map ->
serve walkthrough).

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --smoke   # CI-sized
"""

import argparse

import jax
import numpy as np

from repro import api
from repro.bnn import build_model
from repro.bnn.models import (
    forward_packed, pack_params, prepare_input_packed,
)
from repro.bnn.train import eval_step, init_train_state, train_step
from repro.core import build_mapped_model
from repro.core.mapper import best_uniform
from repro.data import ShardedBatcher, make_image_dataset
from repro.serving import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="shrink model/steps/profiling for CI")
    args = ap.parse_args()
    scale = 0.25 if args.smoke else 0.5
    steps = 10 if args.smoke else 60
    batch_sizes = (1, 4) if args.smoke else (1, 4, 16)
    repeats = 1 if args.smoke else 2

    # 1. train (synthetic Fashion-MNIST stand-in — offline container)
    model = build_model("fashion_mnist", scale=scale)
    ds = make_image_dataset(0, 2048, model.input_hw, model.in_channels)
    state, opt = init_train_state(model, jax.random.PRNGKey(0), lr=2e-3)
    batcher = ShardedBatcher(n=2048, global_batch=64, seed=0)
    for step in range(steps):
        x, y = batcher.batch((ds.x, ds.y), step)
        state, metrics = train_step(model, opt, state, x, y)
    xe, ye = batcher.batch((ds.x, ds.y), 9_999)
    print(f"eval acc after {steps} steps: "
          f"{eval_step(model, state.params, xe, ye):.3f}")

    # 2. quantize -> packed xnor/popcount inference model
    packed = pack_params(model.specs, state.params)

    # 3. HEP-BNN: profile every layer under all 8 implementations,
    #    then map with both policies — the paper's greedy Algorithm 1
    #    and the transfer-aware DP that prices the fused executor
    table = api.profile_model(
        model, packed, batch_sizes=batch_sizes, repeats=repeats
    )
    ec_greedy = api.map_model(table, policy="greedy")
    ec = api.map_model(table, policy="dp")
    print(f"proper batch size: {ec.proper_batch_size}")
    for label, c, k, b in zip(
        ec.layer_labels, ec.layer_configs,
        ec.per_layer_kernel_times, ec.per_layer_boundary_times,
    ):
        print(f"  {label:12s} -> {c:4s} kernel {k*1e6:7.1f}us "
              f"boundary {b*1e6:7.1f}us")
    _, t_xyz = best_uniform(table, "XYZ")
    print(
        f"HEP-dp {ec.expected_time_per_example*1e6:.0f} us/img vs "
        f"HEP-greedy {ec_greedy.expected_time_per_example*1e6:.0f} us/img vs "
        f"full-XYZ {t_xyz*1e6:.0f} us/img "
        f"(dp is {t_xyz/ec.expected_time_per_example:.2f}x vs XYZ, "
        f"{ec_greedy.expected_time_per_example/ec.expected_time_per_example:.2f}x vs greedy)"
    )

    # 4. build + run the mapped model; verify exactness
    mapped = build_mapped_model(model, packed, ec)
    x, _ = batcher.batch((ds.x, ds.y), 123)
    x = x[: ec.proper_batch_size]
    xw = prepare_input_packed(x)
    out = mapped(xw)
    ref = forward_packed(model.specs, packed, xw)
    assert np.array_equal(np.asarray(out), np.asarray(ref))
    print("mapped model output == reference (exact)")

    # 5. serve it: the segment-pipelined engine coalesces single
    #    requests into micro-batches of the proper batch size
    engine = ServingEngine(
        model, packed, ec, allowed_batch_sizes=table.batch_sizes
    )
    n_req = 8
    xw_all = np.asarray(prepare_input_packed(x[:1].repeat(n_req, 0)))
    reqs = [engine.submit(xw_all[i]) for i in range(n_req)]
    engine.step(force=True)
    ref1 = np.asarray(ref)[0]
    assert all(np.array_equal(r.wait(1.0), ref1) for r in reqs)
    segs = " ".join(
        f"[{s.placement[0].upper()}x{len(s)}]" for s in ec.segments()
    )
    print(f"served {n_req} requests through segment schedule {segs} "
          "— responses exact")


if __name__ == "__main__":
    main()
