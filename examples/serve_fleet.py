"""Co-serve two BNN models on one platform through the ``repro.api``
facade: contention-aware joint mapping, SLO router, device-time
ledger — one ``Deployment`` object end to end.

The full fleet loop (docs/ARCHITECTURE.md §10, via §13's facade):

1. ``Deployment.plan`` profiles both models over the near-tied
   CPU/XYZ placement pair and jointly maps them under the
   contention-inflation model (never worse than both-solo-all-GPU),
   persisting the joint mappings in a **fleet-scoped**
   ``ProfileStore`` key (a mapping optimized against these co-runners
   must not warm-start a solo deployment, or another fleet);
2. ``serve(adapt=True)`` stands up the ``FleetRouter``: per-tenant
   priorities and deadlines, admission control shedding requests that
   would miss their SLO, a shared ``DeviceTimeLedger`` metering who
   occupied what, and one tenant-named ``RemapController`` per engine
   (namespaced journals) sharing the fleet store.

Every served response is verified bit-exact against its model's packed
reference.

    PYTHONPATH=src python examples/serve_fleet.py
    PYTHONPATH=src python examples/serve_fleet.py --smoke
"""

import argparse
import tempfile

import jax
import numpy as np

from repro import api
from repro.bnn import build_model
from repro.bnn.models import (
    forward_packed, pack_params, prepare_input_packed,
)
from repro.core.parallel_config import CPU, FULL_GPU
from repro.store import ProfileStore, fleet_scope

SPACE = (CPU, FULL_GPU)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.5)
    ap.add_argument("--requests", type=int, default=128,
                    help="per tenant")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for the CI docs job")
    args = ap.parse_args()
    if args.smoke:
        args.scale, args.requests = 0.25, 32

    names = ("narrow", "wide")
    models = {}
    for name, s in zip(names, (args.scale, args.scale * 1.5)):
        m = build_model("fashion_mnist", scale=s)
        packed = pack_params(m.specs, m.init(jax.random.PRNGKey(0)))
        models[name] = (m, packed)

    # fleet-scoped persistence: these mappings key under this exact
    # co-tenancy — a solo warm start can never pick them up
    store = ProfileStore(
        tempfile.mkdtemp(prefix="fleet_store_"),
        scope=fleet_scope(names),
    )
    dep = api.Deployment.plan(
        models, store=store, configs=SPACE,
        batch_sizes=(args.batch,), gamma=2.0, repeats=1,
        priorities={"narrow": 1},
    )
    plan = dep.fleet_plan
    print(
        f"joint plan: makespan {plan.joint_makespan_s * 1e6:.0f}us "
        f"vs all-GPU {plan.baseline_makespan_s * 1e6:.0f}us "
        f"({plan.vs_all_gpu:.2f}x, {plan.rounds} descent rounds)"
    )
    for t in plan.tenants:
        segs = t.config.segments()
        print(
            f"  {t.name}: "
            + " ".join(f"[{s.placement[0].upper()}x{len(s)}]"
                       for s in segs)
            + f" infl(host={t.host_inflation:.2f}, "
            f"dev={t.device_inflation:.2f})"
        )
    print(f"persisted joint mappings under scope {store.scope}")

    # the narrow tenant is latency-critical: higher priority (set at
    # plan time), a deadline tight enough that backlog bursts get shed
    narrow = dep.tenants["narrow"].config
    narrow_step_s = (
        narrow.expected_time_per_example * narrow.proper_batch_size
    )
    dep.tenants["narrow"].deadline_s = 4.0 * narrow_step_s

    dep.serve(adapt=True, telemetry_sample_every=2)

    n = args.requests
    xs, refs, reqs = {}, {}, {name: [] for name in names}
    for name in names:
        m, packed = models[name]
        x01 = jax.random.uniform(
            jax.random.PRNGKey(7), (n, *m.input_hw, m.in_channels)
        )
        xs[name] = np.asarray(prepare_input_packed(x01))
        refs[name] = np.asarray(forward_packed(m.specs, packed, xs[name]))

    # interleaved trickle: the narrow tenant bursts 2 requests per
    # round, the wide one 1; the router steps as traffic arrives
    i = {name: 0 for name in names}
    while any(i[name] < n for name in names):
        for name, per_round in (("narrow", 2), ("wide", 1)):
            for _ in range(per_round):
                if i[name] < n:
                    reqs[name].append(
                        (i[name], dep.submit(xs[name][i[name]],
                                             tenant=name))
                    )
                    i[name] += 1
        dep.step()
    dep.drain()

    stats = dep.stats()
    for name in names:
        lat_us, shed = [], 0
        for j, r in reqs[name]:
            if r is None:
                shed += 1
                continue
            scores = r.wait(timeout=5.0)
            assert np.array_equal(scores, refs[name][j]), (
                f"{name} response {j} mismatch"
            )
            lat_us.append(r.latency_s * 1e6)
        s = stats["tenants"][name]
        u = stats["ledger"][name]
        print(
            f"{name}: served {s['served']} shed {shed} "
            f"p50 {np.percentile(lat_us, 50):.0f}us "
            f"p99 {np.percentile(lat_us, 99):.0f}us  "
            f"ledger host {u['host_s'] * 1e3:.1f}ms / "
            f"device {u['device_s'] * 1e3:.1f}ms"
        )
        assert s["rejected"] == shed
    print("all served responses verified exact vs per-model references")


if __name__ == "__main__":
    main()
