"""Co-serve two BNN models on one platform with a contention-aware
joint mapping, an SLO router, and a device-time ledger.

The full fleet loop (docs/ARCHITECTURE.md §10):

1. profile both models over the near-tied CPU/XYZ placement pair;
2. ``map_fleet`` — joint coordinate-descent mapping under the
   contention-inflation model (never worse than both-solo-all-GPU);
3. persist the joint mappings in a **fleet-scoped** ``ProfileStore``
   key (a mapping optimized against these co-runners must not
   warm-start a solo deployment, or another fleet);
4. serve interleaved traffic through a ``FleetRouter``: per-tenant
   priorities and deadlines, admission control shedding requests that
   would miss their SLO, a shared ``DeviceTimeLedger`` metering who
   occupied what, and one tenant-named ``RemapController`` per engine
   (namespaced journals) sharing the fleet store.

Every served response is verified bit-exact against its model's packed
reference.

    PYTHONPATH=src python examples/serve_fleet.py
    PYTHONPATH=src python examples/serve_fleet.py --smoke
"""

import argparse
import tempfile

import jax
import numpy as np

from repro.adapt import RemapController, SegmentTelemetry
from repro.bnn import build_model
from repro.bnn.models import (
    forward_packed, pack_params, prepare_input_packed,
)
from repro.core.parallel_config import CPU, FULL_GPU
from repro.core.profiler import profile_bnn_model
from repro.fleet import DeviceTimeLedger, FleetRouter, map_fleet
from repro.serving import ServingEngine
from repro.store import ProfileStore, fleet_scope

SPACE = (CPU, FULL_GPU)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.5)
    ap.add_argument("--requests", type=int, default=128,
                    help="per tenant")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for the CI docs job")
    args = ap.parse_args()
    if args.smoke:
        args.scale, args.requests = 0.25, 32

    names = ("narrow", "wide")
    tenants = {}
    tables = []
    for name, s in zip(names, (args.scale, args.scale * 1.5)):
        m = build_model("fashion_mnist", scale=s)
        packed = pack_params(m.specs, m.init(jax.random.PRNGKey(0)))
        table = profile_bnn_model(
            m, packed, batch_sizes=(args.batch,), configs=SPACE,
            repeats=1,
        )
        tenants[name] = (m, packed, table)
        tables.append(table)

    plan = map_fleet(
        tables, names=names, configs=SPACE,
        batch_sizes=(args.batch,), gamma=2.0,
    )
    print(
        f"joint plan: makespan {plan.joint_makespan_s * 1e6:.0f}us "
        f"vs all-GPU {plan.baseline_makespan_s * 1e6:.0f}us "
        f"({plan.vs_all_gpu:.2f}x, {plan.rounds} descent rounds)"
    )
    for t in plan.tenants:
        segs = t.config.segments()
        print(
            f"  {t.name}: "
            + " ".join(f"[{s.placement[0].upper()}x{len(s)}]"
                       for s in segs)
            + f" infl(host={t.host_inflation:.2f}, "
            f"dev={t.device_inflation:.2f})"
        )

    # fleet-scoped persistence: these mappings key under this exact
    # co-tenancy — a solo warm start can never pick them up
    store = ProfileStore(
        tempfile.mkdtemp(prefix="fleet_store_"),
        scope=fleet_scope(names),
    )
    for name, t in zip(names, plan.tenants):
        store.save_mapping(t.config)
    print(f"persisted joint mappings under scope {store.scope}")

    ledger = DeviceTimeLedger()
    router = FleetRouter(ledger=ledger)
    step_s = {
        name: t.config.expected_time_per_example
        * t.config.proper_batch_size
        for name, t in zip(names, plan.tenants)
    }
    for name, t in zip(names, plan.tenants):
        m, packed, table = tenants[name]
        telemetry = SegmentTelemetry(sample_every=2, tenant=name)
        engine = ServingEngine(
            m, packed, t.config,
            allowed_batch_sizes=table.batch_sizes,
            telemetry=telemetry,
            observer=ledger.observer(name),
        )
        controller = RemapController(engine, table, store=store)
        router.add_tenant(
            name, engine,
            # the narrow tenant is latency-critical: higher priority,
            # a deadline tight enough that backlog bursts get shed
            priority=1 if name == "narrow" else 0,
            deadline_s=(4.0 * step_s[name] if name == "narrow"
                        else float("inf")),
            controller=controller,
        )

    n = args.requests
    xs, refs, reqs = {}, {}, {name: [] for name in names}
    for name in names:
        m, packed, _ = tenants[name]
        x01 = jax.random.uniform(
            jax.random.PRNGKey(7), (n, *m.input_hw, m.in_channels)
        )
        xs[name] = np.asarray(prepare_input_packed(x01))
        refs[name] = np.asarray(forward_packed(m.specs, packed, xs[name]))

    # interleaved trickle: the narrow tenant bursts 2 requests per
    # round, the wide one 1; the router steps as traffic arrives
    i = {name: 0 for name in names}
    while any(i[name] < n for name in names):
        for name, per_round in (("narrow", 2), ("wide", 1)):
            for _ in range(per_round):
                if i[name] < n:
                    reqs[name].append(
                        (i[name], router.submit(name, xs[name][i[name]]))
                    )
                    i[name] += 1
        router.step(force=False)
    router.drain()

    for name in names:
        lat_us, shed = [], 0
        for j, r in reqs[name]:
            if r is None:
                shed += 1
                continue
            scores = r.wait(timeout=5.0)
            assert np.array_equal(scores, refs[name][j]), (
                f"{name} response {j} mismatch"
            )
            lat_us.append(r.latency_s * 1e6)
        s = router.stats()[name]
        u = ledger.usage(name)
        print(
            f"{name}: served {s['served']} shed {shed} "
            f"p50 {np.percentile(lat_us, 50):.0f}us "
            f"p99 {np.percentile(lat_us, 99):.0f}us  "
            f"ledger host {u.host_s * 1e3:.1f}ms / "
            f"device {u.device_s * 1e3:.1f}ms"
        )
        assert s["rejected"] == shed
    print("all served responses verified exact vs per-model references")


if __name__ == "__main__":
    main()
