#!/usr/bin/env python3
"""Enforce coverage floors from a ``coverage.json`` report.

    python tools/check_coverage.py --file coverage.json \
        --path-floor src/repro/estimator=90 --total-floor 60

Reads the JSON report ``pytest --cov ... --cov-report=json:FILE``
writes and fails (exit 1) when any floor is violated:

* ``--path-floor PREFIX=PCT`` (repeatable) — the aggregate line
  coverage of every measured file under ``PREFIX`` must be >= PCT.
  A prefix that matches no measured files is itself a failure: a
  silently-unmeasured package would otherwise pass its floor forever.
* ``--total-floor PCT`` — the repo-wide line coverage must be >= PCT
  (the non-regressing baseline; raise it as coverage grows, never
  lower it to make a PR pass).

Path prefixes are compared with a leading ``src/`` stripped from both
sides, so ``src/repro/estimator`` and ``repro/estimator`` name the
same package regardless of how the report recorded paths.

Pure stdlib (no coverage.py import): CI installs pytest-cov, but this
gate must also be runnable/testable where it is not.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def _norm(path: str) -> str:
    p = path.replace("\\", "/").lstrip("./")
    if p.startswith("src/"):
        p = p[len("src/"):]
    return p


def _under(path: str, prefix: str) -> bool:
    p, pre = _norm(path), _norm(prefix).rstrip("/")
    return p == pre or p.startswith(pre + "/")


def _pct(covered: int, statements: int) -> float:
    if statements <= 0:
        return 100.0
    return 100.0 * covered / statements


def check(report: dict, path_floors: list, total_floor: float | None):
    """Returns a list of human-readable failure strings (empty = pass)."""
    files = report.get("files", {})
    failures = []
    for prefix, floor in path_floors:
        covered = statements = n = 0
        for fname, data in files.items():
            if not _under(fname, prefix):
                continue
            summary = data.get("summary", {})
            covered += int(summary.get("covered_lines", 0))
            statements += int(summary.get("num_statements", 0))
            n += 1
        if n == 0:
            failures.append(
                f"{prefix}: no measured files match this prefix"
            )
            continue
        pct = _pct(covered, statements)
        if pct < floor:
            failures.append(
                f"{prefix}: {pct:.1f}% < floor {floor:.1f}% "
                f"({covered}/{statements} lines over {n} files)"
            )
    if total_floor is not None:
        totals = report.get("totals", {})
        pct = float(
            totals.get(
                "percent_covered",
                _pct(
                    int(totals.get("covered_lines", 0)),
                    int(totals.get("num_statements", 0)),
                ),
            )
        )
        if pct < total_floor:
            failures.append(
                f"TOTAL: {pct:.1f}% < floor {total_floor:.1f}%"
            )
    return failures


def _parse_floor(spec: str):
    prefix, sep, pct = spec.rpartition("=")
    if not sep or not prefix:
        raise argparse.ArgumentTypeError(
            f"expected PREFIX=PCT, got {spec!r}"
        )
    return prefix, float(pct)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--file", type=Path, default=Path("coverage.json"),
                    help="coverage JSON report (default: coverage.json)")
    ap.add_argument("--path-floor", type=_parse_floor, action="append",
                    default=[], metavar="PREFIX=PCT",
                    help="per-package floor; repeatable")
    ap.add_argument("--total-floor", type=float, default=None,
                    metavar="PCT", help="repo-wide floor")
    args = ap.parse_args(argv)
    try:
        report = json.loads(args.file.read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"coverage gate: cannot read {args.file}: {e}")
        return 1
    failures = check(report, args.path_floor, args.total_floor)
    for f in failures:
        print(f"coverage gate FAIL: {f}")
    if not failures:
        print("coverage gate: all floors met")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
