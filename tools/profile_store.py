#!/usr/bin/env python3
"""Operate on a ProfileStore from the command line.

    python tools/profile_store.py inspect [--root DIR | --store URI]
    python tools/profile_store.py stats   [--root DIR | --store URI]
    python tools/profile_store.py gc      [--root DIR] [--max-age-days D]
                                          [--dry-run | --yes]
    python tools/profile_store.py export  [--root DIR] [--out FILE]
    python tools/profile_store.py fit     [--root DIR] [--out FILE]

Every subcommand accepts ``--store URI`` to operate on any cache
backend (``dir://path``, ``sqlite://file.db``, ``mem://name`` — see
``repro.cachesvc``) instead of the default local directory; ``--root``
remains the spelling for plain directory stores.

``inspect`` lists every artifact with its key (fingerprint, model,
registry hash), schema, age, size and — for mappings — whether the
configuration executes any segment through a fused segment-scope
kernel variant (``fused=seg_pallas`` vs ``per-layer``); profile
tables show how many spans carry fused segment rows.  The registry
hash already isolates fused and per-layer registries into different
store keys — this surfaces it so warm-start debugging can tell the
entries apart at a glance.  ``gc`` removes artifacts from
older store schemas plus, with ``--max-age-days``, anything older than
that; it previews by default and deletes only with ``--yes``.
``export`` writes the whole store as one self-contained JSON bundle.
``stats`` prints the backend's counters — entries by kind plus the
hit/miss/put/eviction totals the cache service's popularity ranking
feeds on.
``fit`` trains the learned latency predictor
(``repro.estimator.LatencyPredictor``) on the training rows the store
has accumulated from real profile runs, prints its per-group coverage,
and optionally writes the fitted predictor as JSON for later
``from_json`` loading.

The store layout and keying are documented in
``src/repro/store/profile_store.py`` / docs/ARCHITECTURE.md §9.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

DEFAULT_ROOT = Path("results/profile_store")


def _store(args):
    # deferred: repro.store pulls in jax via the core modules
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
    from repro.store import ProfileStore

    spec = args.store if getattr(args, "store", None) else args.root
    return ProfileStore(spec)


def _fmt_age(age_s: float) -> str:
    if age_s < 120:
        return f"{age_s:.0f}s"
    if age_s < 7200:
        return f"{age_s / 60:.0f}m"
    if age_s < 2 * 86400:
        return f"{age_s / 3600:.1f}h"
    return f"{age_s / 86400:.1f}d"


def _fused_note(e) -> str:
    """Fused-vs-per-layer marker for one entry.  Mappings saved since
    the key carried ``fused_variants`` read straight from the key;
    older mappings fall back to the payload's ``fused_segments``
    (absent = per-layer).  Profile tables report how many spans have
    fused segment rows."""
    key = e.key
    if e.kind == "efficient_configuration":
        names = key.get("fused_variants")
        if names is None:
            try:
                doc = json.loads(e.path.read_text())
                names = sorted(
                    {
                        f["variant"]
                        for f in doc.get("payload", {}).get(
                            "fused_segments", ()
                        )
                    }
                )
            except (OSError, json.JSONDecodeError, KeyError, TypeError):
                return "fused=?"
        return "fused=" + (",".join(names) if names else "per-layer")
    if e.kind == "profile_table":
        spans = key.get("segment_spans")
        if spans:
            return f"segspans={len(spans)}"
        return "segspans=0"
    if e.kind == "training_rows":
        return f"rows={key.get('n_rows', '?')}"
    return ""


def cmd_inspect(args) -> int:
    store = _store(args)
    entries = store.entries()
    for e in entries:
        key = e.key
        note = _fused_note(e)
        print(
            f"{e.kind:24s} v{e.schema}  {_fmt_age(e.age_s):>6s}  "
            f"{e.size_bytes:>8d}B  "
            f"fp={key.get('fingerprint', '?')}  "
            f"model={key.get('model_name', key.get('model', '?'))}  "
            f"r={key.get('registry', '?')}  "
            + (f"{note}  " if note else "")
            + (e.store_key or str(e.path))
        )
    print(f"{len(entries)} entries under {store.backend.uri()}")
    return 0


def cmd_stats(args) -> int:
    store = _store(args)
    s = store.stats()
    by_kind: dict = {}
    for e in store.entries():
        by_kind[e.kind] = by_kind.get(e.kind, 0) + 1
    print(f"backend   {s.get('backend', '?')}  {s.get('uri', '')}")
    print(f"entries   {s.get('entries', 0)}")
    for kind in sorted(by_kind):
        print(f"  {kind:26s} {by_kind[kind]:>6d}")
    for counter in ("hits", "misses", "puts", "deletes", "evictions"):
        print(f"{counter:9s} {s.get(counter, 0)}")
    for tier in ("front", "back"):
        if tier in s:
            ts = s[tier]
            print(f"{tier:9s} {ts.get('uri', '')}  "
                  f"hits={ts.get('hits', 0)} misses={ts.get('misses', 0)} "
                  f"entries={ts.get('entries', 0)}")
    return 0


def cmd_gc(args) -> int:
    store = _store(args)
    max_age_s = (
        None if args.max_age_days is None
        else args.max_age_days * 86400.0
    )
    dry = not args.yes
    removed = store.gc(max_age_s=max_age_s, dry_run=dry)
    verb = "would remove" if dry else "removed"
    for p in removed:
        print(f"{verb} {p}")
    print(f"{verb} {len(removed)} artifacts"
          + ("" if args.yes else " (pass --yes to delete)"))
    return 0


def cmd_export(args) -> int:
    store = _store(args)
    bundle = store.export()
    text = json.dumps(bundle, indent=2) + "\n"
    if args.out is None:
        sys.stdout.write(text)
    else:
        args.out.write_text(text)
        print(f"wrote {args.out} ({len(bundle['entries'])} entries)")
    return 0


def cmd_fit(args) -> int:
    store = _store(args)
    rows = store.load_training_rows()
    if not rows:
        print(f"no training rows under {args.root}; profile something "
              "first (ProfileStore.get_or_profile records rows)")
        return 1
    pred = store.predictor()
    print(f"fitted on {pred.n_rows} rows "
          f"({len(rows) - pred.n_rows} dropped as non-positive)")
    for key, count in sorted(pred.coverage().items()):
        print(f"  {key:28s} {count:>6d} rows")
    if args.out is not None:
        args.out.write_text(pred.to_json() + "\n")
        print(f"wrote {args.out}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    def add(name, help_):
        p = sub.add_parser(name, help=help_)
        p.add_argument("--root", type=Path, default=DEFAULT_ROOT,
                       help=f"store root (default: {DEFAULT_ROOT})")
        p.add_argument("--store", default=None, metavar="URI",
                       help="backend URI (dir:// sqlite:// mem://); "
                            "overrides --root")
        return p

    add("inspect", "list every stored artifact")
    gc = add("gc", "remove stale artifacts")
    gc.add_argument("--max-age-days", type=float, default=None,
                    help="also remove current-schema artifacts older "
                         "than this many days")
    mode = gc.add_mutually_exclusive_group()
    mode.add_argument("--dry-run", action="store_true",
                      help="preview only (the default)")
    mode.add_argument("--yes", action="store_true",
                      help="actually delete")
    ex = add("export", "bundle the store as one JSON")
    ex.add_argument("--out", type=Path, default=None,
                    help="output file (default: stdout)")
    fit = add("fit", "train the latency predictor on stored rows")
    fit.add_argument("--out", type=Path, default=None,
                     help="write the fitted predictor JSON here")
    add("stats", "print backend counters and entry totals")
    args = ap.parse_args(argv)
    return {
        "inspect": cmd_inspect, "gc": cmd_gc, "export": cmd_export,
        "fit": cmd_fit, "stats": cmd_stats,
    }[args.cmd](args)


if __name__ == "__main__":
    sys.exit(main())
