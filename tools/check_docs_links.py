#!/usr/bin/env python
"""Fail on broken relative links in the project docs.

Scans README.md, EXPERIMENTS.md, and every Markdown file under docs/
for ``[text](target)`` links; each non-external target (no scheme,
not a pure #anchor) must resolve to an existing file or directory
relative to the linking file. Used by the CI docs job and
tests/test_docs.py.

    python tools/check_docs_links.py [repo_root]
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
EXTERNAL_RE = re.compile(r"^[a-zA-Z][a-zA-Z0-9+.-]*:")  # http:, mailto:


def doc_files(root: Path) -> list:
    files = [root / "README.md", root / "EXPERIMENTS.md"]
    files += sorted((root / "docs").rglob("*.md"))
    return [f for f in files if f.exists()]


def broken_links(root: Path) -> list:
    """[(doc, target), ...] for every relative link that does not
    resolve."""
    bad = []
    for doc in doc_files(root):
        for target in LINK_RE.findall(doc.read_text()):
            if EXTERNAL_RE.match(target) or target.startswith("#"):
                continue
            path = target.split("#", 1)[0]
            if not (doc.parent / path).exists():
                bad.append((doc.relative_to(root), target))
    return bad


def main(argv: list) -> int:
    root = Path(argv[1]) if len(argv) > 1 else Path(__file__).parents[1]
    docs = doc_files(root)
    missing = [
        n for n in ("README.md", "EXPERIMENTS.md")
        if not (root / n).exists()
    ]
    if missing:
        print(f"missing required docs: {missing}")
        return 1
    bad = broken_links(root)
    for doc, target in bad:
        print(f"{doc}: broken link -> {target}")
    print(
        f"checked {len(docs)} docs: "
        + ("FAIL" if bad else "all relative links resolve")
    )
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
